//! Neural cost model executed via PJRT — the paper's deep-learning
//! model (§3.1) in its transferable, context-encoded form (Fig. 3d).
//!
//! The paper's TreeGRU recurses over a dynamic AST, which cannot be
//! AOT-compiled with static shapes; the paper itself introduces the
//! context-encoded variant for transfer, where each loop level is
//! represented by its context feature vector, embedded, softmax-
//! scattered into memory slots and summed (DESIGN.md §Substitution).
//! That variant is a fixed-shape network over the padded context matrix
//! (`MAX_LOOPS × CONTEXT_DIM`), so we implement it in JAX (L2), lower
//! it **once** to HLO text together with its Adam + rank-loss training
//! step (which itself calls the L1 Pallas matmul kernel), and train /
//! predict from Rust through PJRT. Python never runs at tuning time.
//!
//! Artifacts (see `python/compile/aot.py`):
//! * `costmodel_meta.json` — dimensions (must match [`crate::features`]).
//! * `costmodel_init.f32` — initial flat parameter vector θ.
//! * `costmodel_fwd.hlo.txt` — `(θ, X[Bp,L,D]) → scores[Bp]`.
//! * `costmodel_train.hlo.txt` — one Adam step on the pairwise rank
//!   loss (Eq. 2): `(θ, m, v, t, X[Bt,L,D], y, mask) → (θ', m', v', loss)`.
//! * `costmodel_reg_train.hlo.txt` — same with the regression objective
//!   (the Fig. 5 ablation).

use super::CostModel;
use crate::gbt::Matrix;
use crate::runtime::{literal_f32, require_artifact, to_vec_f32, Executable, PjrtRuntime};
use crate::util::json::Json;
use crate::util::Rng;
use anyhow::{Context, Result};

/// Artifact dimension metadata.
#[derive(Clone, Debug)]
pub struct NeuralMeta {
    /// Flattened parameter-vector length.
    pub theta_dim: usize,
    /// Rows per prediction executable call.
    pub pred_batch: usize,
    /// Rows per train-step executable call.
    pub train_batch: usize,
    /// Padded loop count of the context matrix.
    pub max_loops: usize,
    /// Per-loop context feature width.
    pub context_dim: usize,
}

impl NeuralMeta {
    /// Load `costmodel_meta.json` from the artifact directory.
    pub fn load() -> Result<NeuralMeta> {
        let path = require_artifact("costmodel_meta.json")?;
        let text = std::fs::read_to_string(&path)?;
        let j = Json::parse(&text).context("parsing costmodel_meta.json")?;
        let get = |k: &str| -> Result<usize> {
            Ok(j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow::anyhow!("meta missing {k}"))? as usize)
        };
        Ok(NeuralMeta {
            theta_dim: get("theta_dim")?,
            pred_batch: get("pred_batch")?,
            train_batch: get("train_batch")?,
            max_loops: get("max_loops")?,
            context_dim: get("context_dim")?,
        })
    }
}

/// Training objective variant of the train-step artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NeuralObjective {
    /// Pairwise rank loss.
    Rank,
    /// Squared-error regression.
    Regression,
}

/// The PJRT-executed neural cost model.
pub struct NeuralModel {
    meta: NeuralMeta,
    fwd: Executable,
    train: Executable,
    theta: Vec<f32>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    step: f32,
    /// training epochs per `fit` call
    pub epochs: usize,
    fitted: bool,
    rng: Rng,
    /// label normalization (mean, std) from the last fit
    norm: (f64, f64),
}

impl NeuralModel {
    /// Load artifacts and initial parameters.
    pub fn load(rt: &PjrtRuntime, objective: NeuralObjective, seed: u64) -> Result<Self> {
        let meta = NeuralMeta::load()?;
        anyhow::ensure!(
            meta.max_loops == crate::features::MAX_LOOPS
                && meta.context_dim == crate::features::CONTEXT_DIM,
            "artifact feature dims ({}, {}) do not match crate ({}, {}) — \
             re-run `make artifacts`",
            meta.max_loops,
            meta.context_dim,
            crate::features::MAX_LOOPS,
            crate::features::CONTEXT_DIM
        );
        let fwd = rt.load(require_artifact("costmodel_fwd.hlo.txt")?)?;
        let train_name = match objective {
            NeuralObjective::Rank => "costmodel_train.hlo.txt",
            NeuralObjective::Regression => "costmodel_reg_train.hlo.txt",
        };
        let train = rt.load(require_artifact(train_name)?)?;
        let init_bytes = std::fs::read(require_artifact("costmodel_init.f32")?)?;
        anyhow::ensure!(
            init_bytes.len() == meta.theta_dim * 4,
            "init params size {} != theta_dim {}",
            init_bytes.len() / 4,
            meta.theta_dim
        );
        let theta: Vec<f32> = init_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let d = meta.theta_dim;
        Ok(NeuralModel {
            meta,
            fwd,
            train,
            theta,
            adam_m: vec![0.0; d],
            adam_v: vec![0.0; d],
            step: 0.0,
            epochs: 20,
            fitted: false,
            rng: Rng::seed_from_u64(seed ^ 0x4e55_5241),
            norm: (0.0, 1.0),
        })
    }

    /// One train-step call on a padded minibatch.
    fn train_step(&mut self, x: &[f32], y: &[f32], mask: &[f32]) -> Result<f64> {
        let m = &self.meta;
        self.step += 1.0;
        let inputs = [
            literal_f32(&self.theta, &[m.theta_dim as i64])?,
            literal_f32(&self.adam_m, &[m.theta_dim as i64])?,
            literal_f32(&self.adam_v, &[m.theta_dim as i64])?,
            literal_f32(&[self.step], &[])?,
            literal_f32(x, &[m.train_batch as i64, m.max_loops as i64, m.context_dim as i64])?,
            literal_f32(y, &[m.train_batch as i64])?,
            literal_f32(mask, &[m.train_batch as i64])?,
        ];
        let out = self.train.run(&inputs)?;
        anyhow::ensure!(out.len() == 4, "train step returned {} outputs", out.len());
        self.theta = to_vec_f32(&out[0])?;
        self.adam_m = to_vec_f32(&out[1])?;
        self.adam_v = to_vec_f32(&out[2])?;
        let loss = to_vec_f32(&out[3])?[0] as f64;
        Ok(loss)
    }

    /// Fit on the dataset, returns final epoch mean loss.
    pub fn fit_verbose(&mut self, x: &Matrix, y: &[f64]) -> Result<f64> {
        let m = self.meta.clone();
        let row_len = m.max_loops * m.context_dim;
        anyhow::ensure!(x.cols == row_len, "feature dim {} != {}", x.cols, row_len);
        let n = x.rows;
        if n == 0 {
            return Ok(0.0);
        }
        // z-score labels for stable regression / margins
        let mu = y.iter().sum::<f64>() / n as f64;
        let sd = (y.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / n as f64)
            .sqrt()
            .max(1e-9);
        self.norm = (mu, sd);
        let yn: Vec<f32> = y.iter().map(|v| ((v - mu) / sd) as f32).collect();

        let bt = m.train_batch;
        let mut last_loss = 0.0;
        let mut order: Vec<usize> = (0..n).collect();
        for _ in 0..self.epochs {
            self.rng.shuffle(&mut order);
            let mut losses = Vec::new();
            for chunk in order.chunks(bt) {
                let mut xb = vec![0f32; bt * row_len];
                let mut yb = vec![0f32; bt];
                let mut mb = vec![0f32; bt];
                for (k, &i) in chunk.iter().enumerate() {
                    xb[k * row_len..(k + 1) * row_len].copy_from_slice(x.row(i));
                    yb[k] = yn[i];
                    mb[k] = 1.0;
                }
                losses.push(self.train_step(&xb, &yb, &mb)?);
            }
            last_loss = crate::util::mean(&losses);
        }
        self.fitted = true;
        Ok(last_loss)
    }

    fn predict_impl(&self, x: &Matrix) -> Result<Vec<f64>> {
        let m = &self.meta;
        let row_len = m.max_loops * m.context_dim;
        anyhow::ensure!(x.cols == row_len, "feature dim {} != {}", x.cols, row_len);
        let bp = m.pred_batch;
        let mut out = Vec::with_capacity(x.rows);
        let theta = literal_f32(&self.theta, &[m.theta_dim as i64])?;
        for start in (0..x.rows).step_by(bp) {
            let end = (start + bp).min(x.rows);
            let mut xb = vec![0f32; bp * row_len];
            for (k, i) in (start..end).enumerate() {
                xb[k * row_len..(k + 1) * row_len].copy_from_slice(x.row(i));
            }
            let xl = literal_f32(
                &xb,
                &[bp as i64, m.max_loops as i64, m.context_dim as i64],
            )?;
            let res = self.fwd.run(&[theta.clone(), xl])?;
            let scores = to_vec_f32(&res[0])?;
            for s in scores.iter().take(end - start) {
                out.push(*s as f64 * self.norm.1 + self.norm.0);
            }
        }
        Ok(out)
    }
}

impl CostModel for NeuralModel {
    fn predict(&self, x: &Matrix) -> Vec<f64> {
        if !self.fitted {
            return vec![0.0; x.rows];
        }
        self.predict_impl(x).expect("neural predict failed")
    }

    fn fit(&mut self, x: &Matrix, y: &[f64], _groups: &[usize]) {
        self.fit_verbose(x, y).expect("neural fit failed");
    }

    fn ready(&self) -> bool {
        self.fitted
    }
}

// Integration tests live in rust/tests/runtime_pjrt.rs (they need the
// artifacts built by `make artifacts`).
