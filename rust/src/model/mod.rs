//! Statistical cost models `f̂(x)` (§3.1) behind a common trait, plus
//! acquisition functions (§3.3) and the transfer-learning combination
//! `f̂ = f̂_global + f̂_local` (Eq. 4).
//!
//! * [`GbtModel`] — gradient boosted trees (XGBoost-style, in-crate).
//! * [`EnsembleModel`] — bootstrap ensemble of GBTs exposing
//!   uncertainty for the EI/UCB ablation (Fig. 7).
//! * [`TransferModel`] — frozen global model (trained on `D'` with an
//!   invariant representation) + in-domain local model trained with the
//!   global predictions as base margin (Fig. 8/9).
//! * `neural::NeuralModel` — the context-encoded neural model (Fig. 3d),
//!   executed via AOT-compiled JAX artifacts on PJRT (see
//!   [`crate::runtime`]); the TreeGRU stand-in per DESIGN.md.

pub mod neural;

use crate::gbt::{Gbt, GbtEnsemble, GbtParams, Matrix, PredictPlan};

/// A trainable cost model. Scores follow "higher = better".
/// (Driven from the tuner thread; PJRT-backed models are thread-affine.)
pub trait CostModel {
    /// Predict scores for a batch of feature rows.
    fn predict(&self, x: &Matrix) -> Vec<f64>;

    /// Predict (mean, std); models without uncertainty return std = 0.
    fn predict_stats(&self, x: &Matrix) -> Vec<(f64, f64)> {
        self.predict(x).into_iter().map(|m| (m, 0.0)).collect()
    }

    /// Retrain from the complete dataset (the paper retrains on all of
    /// `D` after each measurement batch). `groups` are contiguous group
    /// sizes for rank objectives.
    fn fit(&mut self, x: &Matrix, y: &[f64], groups: &[usize]);

    /// Whether the model has been fitted at least once.
    fn ready(&self) -> bool;

    /// Clone a frozen copy of the model for cross-thread scoring — the
    /// pipelined tuner ([`crate::tuner::pipeline`]) ships one snapshot
    /// per fit epoch to its proposal stage. Models that cannot be
    /// cloned across threads (e.g. the PJRT-backed neural model, whose
    /// executables are thread-affine) keep the default `None` and are
    /// run under the serial reference schedule instead.
    fn snapshot(&self) -> Option<Box<dyn CostModel + Send>> {
        None
    }
}

/// GBT-backed cost model. With fast paths on (the default), every
/// `fit` compiles the trained model into a [`PredictPlan`] and
/// `predict` routes through the plan's binned batch walk — bit-exact
/// with the scalar reference, so the toggle exists purely for honest
/// A/B timing (`TuneOptions::fast_paths`, `bench_gbt`).
pub struct GbtModel {
    /// Boosting hyper-parameters.
    pub params: GbtParams,
    model: Option<Gbt>,
    plan: Option<PredictPlan>,
    use_plan: bool,
}

impl GbtModel {
    /// Unfitted model with the given hyper-parameters (plan-routed
    /// prediction on).
    pub fn new(params: GbtParams) -> Self {
        Self::with_fast_paths(params, true)
    }

    /// Unfitted model; `fast` selects plan-routed vs scalar prediction.
    pub fn with_fast_paths(params: GbtParams, fast: bool) -> Self {
        GbtModel { params, model: None, plan: None, use_plan: fast }
    }
}

impl CostModel for GbtModel {
    fn predict(&self, x: &Matrix) -> Vec<f64> {
        match (&self.plan, &self.model) {
            (Some(p), _) => p.predict_batch(x),
            (None, Some(m)) => m.predict_batch(x),
            (None, None) => vec![0.0; x.rows],
        }
    }

    fn fit(&mut self, x: &Matrix, y: &[f64], groups: &[usize]) {
        if x.rows == 0 {
            return;
        }
        let m = Gbt::train(x, y, groups, self.params.clone());
        self.plan = self.use_plan.then(|| m.compile());
        self.model = Some(m);
    }

    fn ready(&self) -> bool {
        self.model.is_some()
    }

    fn snapshot(&self) -> Option<Box<dyn CostModel + Send>> {
        Some(Box::new(GbtModel {
            params: self.params.clone(),
            model: self.model.clone(),
            plan: self.plan.clone(),
            use_plan: self.use_plan,
        }))
    }
}

/// Bootstrap-ensemble model with uncertainty (Fig. 7 ablation). The
/// paper uses 5 bootstrap models with the regression objective. With
/// fast paths on, each member compiles to a [`PredictPlan`] at fit
/// time and `predict_stats` runs every member through its plan; the
/// (mean, std) reduction is shared with the scalar path
/// ([`crate::gbt::stats_from_members`]), so stats stay bit-identical.
pub struct EnsembleModel {
    /// Per-member boosting hyper-parameters.
    pub params: GbtParams,
    /// Number of bootstrap members.
    pub k: usize,
    model: Option<GbtEnsemble>,
    plans: Vec<PredictPlan>,
    use_plan: bool,
}

impl EnsembleModel {
    /// Unfitted `k`-member ensemble (plan-routed prediction on).
    pub fn new(params: GbtParams, k: usize) -> Self {
        Self::with_fast_paths(params, k, true)
    }

    /// Unfitted `k`-member ensemble; `fast` selects plan-routed vs
    /// scalar member prediction.
    pub fn with_fast_paths(params: GbtParams, k: usize, fast: bool) -> Self {
        EnsembleModel { params, k, model: None, plans: Vec::new(), use_plan: fast }
    }
}

impl CostModel for EnsembleModel {
    fn predict(&self, x: &Matrix) -> Vec<f64> {
        self.predict_stats(x).into_iter().map(|(m, _)| m).collect()
    }

    fn predict_stats(&self, x: &Matrix) -> Vec<(f64, f64)> {
        if !self.plans.is_empty() {
            let per: Vec<Vec<f64>> =
                self.plans.iter().map(|p| p.predict_batch(x)).collect();
            return crate::gbt::stats_from_members(&per, x.rows);
        }
        match &self.model {
            Some(m) => m.predict_stats(x),
            None => vec![(0.0, 0.0); x.rows],
        }
    }

    fn fit(&mut self, x: &Matrix, y: &[f64], _groups: &[usize]) {
        if x.rows == 0 {
            return;
        }
        let ens = GbtEnsemble::train(x, y, self.k, self.params.clone());
        self.plans = if self.use_plan {
            ens.members.iter().map(Gbt::compile).collect()
        } else {
            Vec::new()
        };
        self.model = Some(ens);
    }

    fn ready(&self) -> bool {
        self.model.is_some()
    }

    fn snapshot(&self) -> Option<Box<dyn CostModel + Send>> {
        Some(Box::new(EnsembleModel {
            params: self.params.clone(),
            k: self.k,
            model: self.model.clone(),
            plans: self.plans.clone(),
            use_plan: self.use_plan,
        }))
    }
}

/// Acquisition functions over (mean, std) — §3.3 "Uncertainty
/// Estimator". With `Mean` the search uses f̂ directly (the paper's
/// default); `Ucb`/`Ei` are the Bayesian-optimization alternatives the
/// paper evaluates and finds unhelpful (Fig. 7).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Acquisition {
    /// Use the predicted mean directly (the paper's default).
    Mean,
    /// mean + κ·std
    Ucb(f64),
    /// expected improvement over `best`
    Ei,
}

impl Acquisition {
    /// Score a candidate (higher = more desirable to try).
    pub fn score(self, mean: f64, std: f64, best: f64) -> f64 {
        match self {
            Acquisition::Mean => mean,
            Acquisition::Ucb(kappa) => mean + kappa * std,
            Acquisition::Ei => {
                if std <= 1e-12 {
                    return (mean - best).max(0.0);
                }
                let z = (mean - best) / std;
                // EI = (μ-b)Φ(z) + σφ(z)
                (mean - best) * phi_cdf(z) + std * phi_pdf(z)
            }
        }
    }
}

fn phi_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF via the Abramowitz–Stegun erf approximation.
fn phi_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    // A&S 7.1.26, |error| ≤ 1.5e-7
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Relative gradient weight of cross-target source rows (tier 2) in a
/// tiered warm start: records measured on *another* device rank below
/// same-target sibling records (weight 1.0) but still shape the global
/// model — [`Representation::ContextRelation`] features are
/// target-invariant, so the *ordering* signal transfers even though
/// absolute throughput does not.
///
/// [`Representation::ContextRelation`]: crate::features::Representation::ContextRelation
pub const CROSS_TARGET_WEIGHT: f64 = 0.3;

/// What a tiered warm start ([`TransferModel::warm_start_tiered`]) was
/// built from — callers use it to log the provenance of the global
/// model (the multi-target report greps for the cross-target line).
#[derive(Clone, Debug)]
pub struct WarmStartStats {
    /// Same-target sibling tasks offered to tier 1.
    pub same_target_tasks: usize,
    /// Training rows contributed by tier 1 (same-target siblings).
    pub same_target_rows: usize,
    /// Other targets contributing tier-2 rows, in sorted order.
    pub cross_targets: Vec<String>,
    /// Training rows contributed by tier 2 (cross-target records).
    pub cross_target_rows: usize,
}

impl WarmStartStats {
    /// Whether any cross-target rows entered the global model.
    pub fn used_cross_target(&self) -> bool {
        self.cross_target_rows > 0
    }
}

/// Transfer-learning model (Eq. 4): a frozen global model plus a local
/// model trained on the current task. The local model is trained with
/// the (linearly calibrated) global predictions as base margin, so
/// `predict = calibrate(global) + local_trees` — the additive
/// combination of the paper.
pub struct TransferModel {
    global: Gbt,
    /// Compiled at construction: global scoring is always plan-routed
    /// (bit-exact with the scalar walk, so no toggle is needed here).
    global_plan: PredictPlan,
    /// linear calibration of global scores to local label scale
    calib: (f64, f64),
    local: Option<Gbt>,
    local_plan: Option<PredictPlan>,
    /// Hyper-parameters of the local model.
    pub params: GbtParams,
}

impl TransferModel {
    /// Train the global model on the source-domain dataset `D'`.
    pub fn from_source(
        x: &Matrix,
        y: &[f64],
        groups: &[usize],
        params: GbtParams,
    ) -> TransferModel {
        let global = Gbt::train(x, y, groups, params.clone());
        let global_plan = global.compile();
        TransferModel {
            global,
            global_plan,
            calib: (1.0, 0.0),
            local: None,
            local_plan: None,
            params,
        }
    }

    /// [`from_source`](Self::from_source) with a weight per rank group
    /// ([`Gbt::train_weighted`]) — the tiered warm start trains its
    /// global model through this, same-target groups at 1.0 and
    /// cross-target groups at [`CROSS_TARGET_WEIGHT`].
    pub fn from_source_weighted(
        x: &Matrix,
        y: &[f64],
        groups: &[usize],
        group_weights: &[f64],
        params: GbtParams,
    ) -> TransferModel {
        let global = Gbt::train_weighted(x, y, groups, group_weights, params.clone());
        let global_plan = global.compile();
        TransferModel {
            global,
            global_plan,
            calib: (1.0, 0.0),
            local: None,
            local_plan: None,
            params,
        }
    }

    /// The one warm-start entry point of the service layer: given the
    /// shared DB and an inventory of `candidates` the caller knows how
    /// to lower, build the Eq.-4 global model for `target_task` from
    /// every *other* candidate's records on `target`, under the
    /// invariant [`Representation::ContextRelation`] (the only
    /// representation that transfers across operator types and
    /// templates). Candidates without records are skipped before any
    /// featurization. Returns `None` when the DB holds nothing usable,
    /// so callers fall back to a cold start.
    ///
    /// Both warm-start paths — the coordinator's
    /// (`experiments::warm_start_model`, over the full known-task
    /// inventory) and the graph scheduler's (`LoopExecutor`, over the
    /// plan's sibling tasks) — are thin wrappers over this function;
    /// they differ only in which inventory they pass. Since the
    /// heterogeneous-fleet tier this delegates to
    /// [`warm_start_tiered`](Self::warm_start_tiered), which
    /// additionally folds in down-weighted records from *other*
    /// targets; callers that want the provenance call the tiered entry
    /// point directly.
    ///
    /// [`Representation::ContextRelation`]: crate::features::Representation::ContextRelation
    pub fn warm_start(
        db: &crate::tuner::db::TuningDb,
        candidates: &[crate::schedule::template::Task],
        target_task: &crate::schedule::template::Task,
        target: &str,
        objective: crate::gbt::Objective,
        seed: u64,
    ) -> Option<TransferModel> {
        Self::warm_start_tiered(db, candidates, target_task, target, objective, seed)
            .map(|(m, _)| m)
    }

    /// [`warm_start`](Self::warm_start), reporting provenance — and the
    /// home of the **cross-target source tier**. `D'` is assembled in
    /// two tiers of rank groups:
    ///
    /// * **Tier 1 (weight 1.0)** — records of sibling candidates on
    ///   `target` itself, exactly what [`warm_start`](Self::warm_start)
    ///   always used.
    /// * **Tier 2 (weight [`CROSS_TARGET_WEIGHT`])** — records of any
    ///   candidate (including `target_task`'s own siblings under
    ///   another template) on *other* targets present in the DB. The
    ///   invariant representation makes these rows featurize
    ///   byte-identically to same-target rows, and per-task label
    ///   normalization plus the rank objective mean only within-task
    ///   *order* is learned — the part that transfers across devices.
    ///
    /// With no cross-target rows in the DB the trained model is
    /// bit-identical to the tier-1-only [`warm_start`](Self::warm_start)
    /// of old (unit weights reproduce unweighted training exactly). A
    /// CPU-warm-started GPU search — tier 1 empty because templates
    /// differ per device class, tier 2 carrying the CPU records — is
    /// the case the old single-tier path returned `None` for.
    pub fn warm_start_tiered(
        db: &crate::tuner::db::TuningDb,
        candidates: &[crate::schedule::template::Task],
        target_task: &crate::schedule::template::Task,
        target: &str,
        objective: crate::gbt::Objective,
        seed: u64,
    ) -> Option<(TransferModel, WarmStartStats)> {
        if db.is_empty() {
            return None;
        }
        let target = crate::tuner::db::canonical_target(target);
        let target_key = target_task.key();
        let repr = crate::features::Representation::ContextRelation;
        // Tier 1: same-target siblings.
        let have: std::collections::HashSet<String> =
            db.task_keys(&target).into_iter().collect();
        let tier1: Vec<&crate::schedule::template::Task> = candidates
            .iter()
            .filter(|t| {
                let k = t.key();
                k != target_key && have.contains(&k)
            })
            .collect();
        let (x1, y1, g1) = if tier1.is_empty() {
            (Matrix::default(), Vec::new(), Vec::new())
        } else {
            db.to_training(&tier1, &target, repr, usize::MAX)
        };
        let mut stats = WarmStartStats {
            same_target_tasks: tier1.len(),
            same_target_rows: x1.rows,
            cross_targets: Vec::new(),
            cross_target_rows: 0,
        };
        let mut rows = x1.rows;
        let mut cols = x1.cols;
        let mut data = x1.data;
        let mut ys = y1;
        let mut groups = g1;
        let mut weights = vec![1.0; groups.len()];
        // Tier 2: every other target in the DB, in sorted order for
        // determinism. The target task's own key is *not* excluded
        // here — its records on another device are the cross-device
        // signal this tier exists for.
        let mut others: Vec<String> =
            db.shard_keys().into_iter().map(|(_, t)| t).filter(|t| *t != target).collect();
        others.sort();
        others.dedup();
        for t2 in others {
            let have2: std::collections::HashSet<String> =
                db.task_keys(&t2).into_iter().collect();
            let srcs: Vec<&crate::schedule::template::Task> =
                candidates.iter().filter(|t| have2.contains(&t.key())).collect();
            if srcs.is_empty() {
                continue;
            }
            let (x2, y2, g2) = db.to_training(&srcs, &t2, repr, usize::MAX);
            if x2.rows == 0 {
                continue;
            }
            if cols == 0 {
                cols = x2.cols;
            }
            if x2.cols != cols {
                // representation widths must agree to concatenate; an
                // incompatible source tier is skipped, not fatal
                continue;
            }
            data.extend_from_slice(&x2.data);
            rows += x2.rows;
            ys.extend(y2);
            weights.extend(std::iter::repeat(CROSS_TARGET_WEIGHT).take(g2.len()));
            groups.extend(g2);
            stats.cross_target_rows += x2.rows;
            stats.cross_targets.push(t2);
        }
        if rows == 0 {
            return None;
        }
        let x = Matrix::new(rows, cols, data);
        let params = GbtParams { objective, seed, ..Default::default() };
        let model = if stats.used_cross_target() {
            TransferModel::from_source_weighted(&x, &ys, &groups, &weights, params)
        } else {
            // unit weights ≡ unweighted training, but route through the
            // plain path anyway: the tier-1-only result must stay
            // bit-identical to the pre-tiering warm start
            TransferModel::from_source(&x, &ys, &groups, params)
        };
        Some((model, stats))
    }

    /// Build the Eq.-4 global model straight from the tuning-DB service
    /// layer: `D'` is every valid record of `source_tasks` on `target`
    /// (minus `exclude_task_key`, the task about to be tuned),
    /// featurized under `repr` — use an invariant representation
    /// ([`Representation::ContextRelation`]) so the model transfers
    /// across operator types and templates. Returns `None` when the DB
    /// holds no usable source rows, so callers can fall back to a cold
    /// start. Most callers want the higher-level
    /// [`warm_start`](Self::warm_start) instead.
    ///
    /// [`Representation::ContextRelation`]: crate::features::Representation::ContextRelation
    pub fn from_db(
        db: &crate::tuner::db::TuningDb,
        source_tasks: &[&crate::schedule::template::Task],
        exclude_task_key: &str,
        target: &str,
        repr: crate::features::Representation,
        limit_per_task: usize,
        params: GbtParams,
    ) -> Option<TransferModel> {
        let sources: Vec<&crate::schedule::template::Task> = source_tasks
            .iter()
            .copied()
            .filter(|t| t.key() != exclude_task_key)
            .collect();
        if sources.is_empty() {
            return None;
        }
        let (x, y, groups) = db.to_training(&sources, target, repr, limit_per_task);
        if x.rows == 0 {
            return None;
        }
        Some(TransferModel::from_source(&x, &y, &groups, params))
    }

    fn global_scores(&self, x: &Matrix) -> Vec<f64> {
        self.global_plan.predict_batch(x)
    }
}

impl CostModel for TransferModel {
    fn predict(&self, x: &Matrix) -> Vec<f64> {
        let g = self.global_scores(x);
        let (a, b) = self.calib;
        match &self.local_plan {
            Some(l) => {
                let lp = l.predict_batch(x);
                g.iter().zip(lp).map(|(gi, li)| a * gi + b + li).collect()
            }
            None => g.iter().map(|gi| a * gi + b).collect(),
        }
    }

    fn fit(&mut self, x: &Matrix, y: &[f64], groups: &[usize]) {
        if x.rows == 0 {
            return;
        }
        let g = self.global_scores(x);
        // least-squares calibration y ≈ a·g + b
        let n = x.rows as f64;
        let mg = g.iter().sum::<f64>() / n;
        let my = y.iter().sum::<f64>() / n;
        let cov: f64 = g.iter().zip(y).map(|(gi, yi)| (gi - mg) * (yi - my)).sum();
        let var: f64 = g.iter().map(|gi| (gi - mg) * (gi - mg)).sum();
        let a = if var > 1e-12 { cov / var } else { 0.0 };
        let b = my - a * mg;
        self.calib = (a, b);
        let margin: Vec<f64> = g.iter().map(|gi| a * gi + b).collect();
        let local = Gbt::train_with_margin(x, y, groups, &margin, self.params.clone());
        self.local_plan = Some(local.compile());
        self.local = Some(local);
    }

    /// Global model alone is already usable.
    fn ready(&self) -> bool {
        true
    }

    /// Transfer models snapshot cleanly, so the pipelined loop gets the
    /// same warm start as the serial one: the epoch-0 snapshot is the
    /// global model, making even the first SA round informed.
    fn snapshot(&self) -> Option<Box<dyn CostModel + Send>> {
        Some(Box::new(TransferModel {
            global: self.global.clone(),
            global_plan: self.global_plan.clone(),
            calib: self.calib,
            local: self.local.clone(),
            local_plan: self.local_plan.clone(),
            params: self.params.clone(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbt::Objective;
    use crate::util::Rng;

    fn synth(n: usize, seed: u64, shift: f64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let r: Vec<f64> = (0..6).map(|_| rng.gen_f64() * 4.0).collect();
            y.push(2.0 * r[0] - r[1] * r[2] + shift);
            rows.push(r);
        }
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn gbt_model_lifecycle() {
        let (x, y) = synth(500, 1, 0.0);
        let mut m = GbtModel::new(GbtParams {
            objective: Objective::Regression,
            n_trees: 30,
            ..Default::default()
        });
        assert!(!m.ready());
        assert_eq!(m.predict(&x), vec![0.0; 500]);
        m.fit(&x, &y, &[]);
        assert!(m.ready());
        let acc = crate::gbt::rank_accuracy(&m.predict(&x), &y);
        assert!(acc > 0.9, "in-sample rank acc {acc}");
    }

    #[test]
    fn fast_and_scalar_models_agree_bitwise() {
        let (x, y) = synth(400, 9, 0.0);
        let params =
            GbtParams { objective: Objective::Regression, n_trees: 20, ..Default::default() };
        let mut fast = GbtModel::new(params.clone());
        let mut scalar = GbtModel::with_fast_paths(params.clone(), false);
        fast.fit(&x, &y, &[]);
        scalar.fit(&x, &y, &[]);
        assert_eq!(fast.predict(&x), scalar.predict(&x));
        let mut efast = EnsembleModel::new(params.clone(), 3);
        let mut escalar = EnsembleModel::with_fast_paths(params, 3, false);
        efast.fit(&x, &y, &[]);
        escalar.fit(&x, &y, &[]);
        assert_eq!(efast.predict_stats(&x), escalar.predict_stats(&x));
    }

    #[test]
    fn ensemble_model_has_uncertainty() {
        let (x, y) = synth(300, 2, 0.0);
        let mut m = EnsembleModel::new(
            GbtParams { objective: Objective::Regression, n_trees: 10, ..Default::default() },
            5,
        );
        m.fit(&x, &y, &[]);
        let stats = m.predict_stats(&x);
        assert!(stats.iter().any(|(_, s)| *s > 0.0));
    }

    #[test]
    fn acquisition_functions_behave() {
        // UCB rewards uncertainty
        assert!(Acquisition::Ucb(2.0).score(1.0, 1.0, 0.0) > Acquisition::Mean.score(1.0, 1.0, 0.0));
        // EI is 0 for hopeless certain candidates, positive for uncertain
        assert_eq!(Acquisition::Ei.score(0.0, 0.0, 5.0), 0.0);
        assert!(Acquisition::Ei.score(0.0, 2.0, 0.5) > 0.0);
        // EI increases with mean
        assert!(
            Acquisition::Ei.score(2.0, 1.0, 1.0) > Acquisition::Ei.score(0.0, 1.0, 1.0)
        );
    }

    #[test]
    fn erf_reference_values() {
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf(-1.0) + 0.8427007).abs() < 1e-5);
        assert!((phi_cdf(0.0) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn transfer_model_beats_cold_start_with_little_data() {
        // source domain: same function, shifted labels
        let (xs, ys) = synth(3000, 3, 10.0);
        let params = GbtParams {
            objective: Objective::Regression,
            n_trees: 40,
            ..Default::default()
        };
        let transfer = TransferModel::from_source(&xs, &ys, &[], params.clone());
        // tiny target dataset
        let (xt, yt) = synth(30, 4, 0.0);
        let (xe, ye) = synth(400, 5, 0.0);
        let mut cold = GbtModel::new(params.clone());
        cold.fit(&xt, &yt, &[]);
        let mut warm = transfer;
        warm.fit(&xt, &yt, &[]);
        let acc_cold = crate::gbt::rank_accuracy(&cold.predict(&xe), &ye);
        let acc_warm = crate::gbt::rank_accuracy(&warm.predict(&xe), &ye);
        assert!(
            acc_warm > acc_cold - 0.02,
            "transfer {acc_warm} much worse than cold {acc_cold}"
        );
        assert!(acc_warm > 0.8, "transfer model weak: {acc_warm}");
    }

    #[test]
    fn tiered_warm_start_uses_cross_target_records() {
        use crate::expr::ops;
        use crate::measure::Measurer;
        use crate::schedule::template::{Task, TemplateKind};
        let cpu_task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let gpu_task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let db = crate::tuner::db::TuningDb::new();
        let m = crate::measure::SimMeasurer::with_seed(crate::sim::devices::sim_cpu(), 1);
        let mut rng = Rng::seed_from_u64(2);
        let batch: Vec<_> = (0..24).map(|_| cpu_task.space.sample(&mut rng)).collect();
        let res = m.measure(&cpu_task, &batch);
        let recs: Vec<crate::tuner::TrialRecord> = batch
            .into_iter()
            .zip(res)
            .map(|(e, r)| crate::tuner::TrialRecord {
                entity: e,
                gflops: r.gflops,
                seconds: r.seconds,
                error: r.error,
            })
            .collect();
        db.add_run(&cpu_task, "sim-cpu", &recs).unwrap();
        // tier 1 is empty (no sim-gpu records, and the GPU template is a
        // different task key) — the pre-tiering warm start had nothing;
        // the cross-target tier warm-starts the GPU search from the CPU
        // records
        let candidates = vec![cpu_task.clone(), gpu_task.clone()];
        let (model, stats) = TransferModel::warm_start_tiered(
            &db,
            &candidates,
            &gpu_task,
            "sim-gpu",
            Objective::Rank,
            0,
        )
        .expect("cross-target tier should produce a model");
        assert!(stats.used_cross_target());
        assert_eq!(stats.same_target_rows, 0);
        assert_eq!(stats.cross_targets, vec!["sim-cpu".to_string()]);
        assert!(model.ready());
    }

    #[test]
    fn transfer_model_usable_before_local_fit() {
        let (xs, ys) = synth(1000, 6, 0.0);
        let params = GbtParams {
            objective: Objective::Regression,
            n_trees: 30,
            ..Default::default()
        };
        let m = TransferModel::from_source(&xs, &ys, &[], params);
        assert!(m.ready());
        let (xe, ye) = synth(200, 7, 0.0);
        let acc = crate::gbt::rank_accuracy(&m.predict(&xe), &ye);
        assert!(acc > 0.8, "global-only acc {acc}");
    }
}
