//! Program representations for the cost models (§4, Fig. 3, Table 2).
//!
//! Four representations with increasing invariance, matching Fig. 9:
//!
//! * [`Representation::Config`] — knob values (a batched SMAC-style
//!   Bayesian-optimization baseline). Not invariant to the search space.
//! * [`Representation::FlatAst`] — per-loop context rows of the longest
//!   chain, flattened with padding. Invariant to the space, but ties
//!   feature positions to the loop-nest pattern, so it transfers within
//!   an operator type only.
//! * [`Representation::ContextRelation`] — the paper's transferable
//!   representation: context *relation* features
//!   `R_t^{(ij)} = max_{k : Z_kj < β_t} Z_ki` over log2-spaced
//!   thresholds β, plus nest-size-invariant pooled context.
//! * [`Representation::Full`] — FlatAst ⧺ ContextRelation ⧺ globals;
//!   the default in-domain GBT feature set.
//!
//! The per-loop context row follows Table 2 of the paper: loop length,
//! one-hot annotation, top-down and bottom-up extent products, and per
//! touched buffer the touch count, reuse ratio, stride and memory scope.

use crate::ast::analysis::{ProgramAnalysis, StoreChain};
use crate::ast::{ForKind, MemScope};

/// Buffers tracked per loop level.
pub const N_BUFS: usize = 3;
/// Per-loop context feature dimension.
pub const CONTEXT_DIM: usize = 1 + ForKind::COUNT + 2 + N_BUFS * 4;
/// Loop-padding for fixed-shape representations (deepest real nests in
/// our templates are conv2d with 4+3 axes split 3/2-way ≈ 15 loops).
pub const MAX_LOOPS: usize = 16;
/// Global (chain-level) feature dimension.
pub const GLOBAL_DIM: usize = 5;
/// Number of log2-spaced relation thresholds.
pub const N_THRESHOLDS: usize = 12;
/// Relation feature pairs: (touch, reuse) and (touch, top-down), as in
/// the paper's appendix A.2.2.
pub const N_PAIRS: usize = 2;

/// Dimension of the flat-AST representation.
pub const FLAT_DIM: usize = MAX_LOOPS * CONTEXT_DIM;
/// Dimension of the context-relation representation.
pub const RELATION_DIM: usize = N_PAIRS * N_THRESHOLDS + 2 * CONTEXT_DIM + GLOBAL_DIM;
/// Dimension of the full representation.
pub const FULL_DIM: usize = FLAT_DIM + RELATION_DIM;
/// Fixed dimension config features are padded/truncated to (for the
/// cross-domain comparison of Fig. 9).
pub const CONFIG_DIM: usize = 24;

/// Which representation to extract (the Fig. 9 axis). `Hash` lets the
/// tuning DB key its per-task feature caches by representation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Representation {
    /// Raw knob values (SMAC-style baseline; not space-invariant).
    Config,
    /// Flattened per-loop context rows of the longest chain.
    FlatAst,
    /// The paper's transferable context-relation features.
    ContextRelation,
    /// FlatAst ⧺ ContextRelation ⧺ globals (in-domain default).
    Full,
}

impl Representation {
    /// Feature-vector dimension of this representation.
    pub fn dim(self) -> usize {
        match self {
            Representation::Config => CONFIG_DIM,
            Representation::FlatAst => FLAT_DIM,
            Representation::ContextRelation => RELATION_DIM,
            Representation::Full => FULL_DIM,
        }
    }
}

fn log2p(x: f64) -> f64 {
    (x.max(0.0) + 1.0).log2()
}

/// Per-loop context rows (Table 2) for one chain: `loops × CONTEXT_DIM`.
pub fn context_rows(chain: &StoreChain) -> Vec<[f64; CONTEXT_DIM]> {
    let n = chain.loops.len();
    let mut rows = Vec::with_capacity(n);
    // rank buffers by total touch (store target first, then largest)
    let mut order: Vec<usize> = (0..chain.accesses.len()).collect();
    order.sort_by(|&a, &b| {
        let ka = (chain.accesses[a].is_write, chain.accesses[a].touch.first().copied());
        let kb = (chain.accesses[b].is_write, chain.accesses[b].touch.first().copied());
        kb.partial_cmp(&ka).unwrap()
    });
    order.truncate(N_BUFS);

    for l in 0..n {
        let mut row = [0f64; CONTEXT_DIM];
        let mut i = 0;
        row[i] = log2p(chain.loops[l].extent as f64);
        i += 1;
        row[i + chain.loops[l].kind.one_hot_index()] = 1.0;
        i += ForKind::COUNT;
        row[i] = log2p(chain.top_down[l]);
        row[i + 1] = log2p(chain.bottom_up[l]);
        i += 2;
        for &ai in &order {
            let a = &chain.accesses[ai];
            row[i] = log2p(a.touch[l]);
            row[i + 1] = log2p(a.reuse[l]);
            row[i + 2] = log2p(a.strides[l].unsigned_abs() as f64);
            row[i + 3] = match a.scope {
                MemScope::Global => 0.0,
                MemScope::Shared => 0.5,
                MemScope::Local => 1.0,
            };
            i += 4;
        }
        rows.push(row);
    }
    rows
}

/// Global chain summary features.
fn global_features(analysis: &ProgramAnalysis) -> [f64; GLOBAL_DIM] {
    let main = analysis.longest_chain();
    let total_trip: f64 = analysis.chains.iter().map(|c| c.trip).sum();
    let shared_trip: f64 = analysis
        .chains
        .iter()
        .filter(|c| c.accesses[0].scope == MemScope::Shared)
        .map(|c| c.trip)
        .sum();
    [
        log2p(analysis.chains.len() as f64),
        log2p(total_trip),
        log2p(shared_trip),
        main.value_flops as f64,
        main.has_guard as u8 as f64,
    ]
}

/// Flat-AST representation into a `FLAT_DIM` slice: padded/truncated
/// context rows of the longest chain.
pub fn flat_ast_into(analysis: &ProgramAnalysis, out: &mut [f64]) {
    debug_assert_eq!(out.len(), FLAT_DIM);
    out.fill(0.0);
    let rows = context_rows(analysis.longest_chain());
    for (l, row) in rows.iter().take(MAX_LOOPS).enumerate() {
        out[l * CONTEXT_DIM..(l + 1) * CONTEXT_DIM].copy_from_slice(row);
    }
}

/// Flat-AST representation: padded/truncated context rows of the
/// longest chain.
pub fn flat_ast(analysis: &ProgramAnalysis) -> Vec<f64> {
    let mut out = vec![0f64; FLAT_DIM];
    flat_ast_into(analysis, &mut out);
    out
}

/// Relation features over one precomputed context matrix:
/// for pair (i, j) and threshold t, `R_t = max_{k: Z_kj < β_t} Z_ki`.
///
/// Column i = touch count (log2), column j ∈ {reuse ratio, top-down}.
/// Thresholds are log2-spaced: β_t = t · 2 in log2 space (i.e. 4^t).
/// Taking the rows (instead of the chain) lets [`context_relation_into`]
/// compute the context matrix once for both the relation and the pooled
/// features.
fn relation_pairs_into(rows: &[[f64; CONTEXT_DIM]], out: &mut [f64]) {
    let touch_col = 1 + ForKind::COUNT + 2; // first buffer's touch
    let reuse_col = touch_col + 1;
    let td_col = 1 + ForKind::COUNT;
    for pair in 0..N_PAIRS {
        for t in 0..N_THRESHOLDS {
            let beta = (t as f64 + 1.0) * 2.0; // log2-spaced thresholds
            let val = rows
                .iter()
                .filter(|r| {
                    let zj = if pair == 0 { r[reuse_col] } else { r[td_col] };
                    zj < beta
                })
                .map(|r| r[touch_col])
                .fold(0.0, f64::max);
            out[pair * N_THRESHOLDS + t] = val;
        }
    }
}

/// Context-relation representation into a `RELATION_DIM` slice:
/// relation pairs + per-dim max/mean pooled context rows + globals.
/// The context matrix of the longest chain is computed once and shared
/// by the relation and pooled sections.
pub fn context_relation_into(analysis: &ProgramAnalysis, out: &mut [f64]) {
    debug_assert_eq!(out.len(), RELATION_DIM);
    let chain = analysis.longest_chain();
    let rows = context_rows(chain);
    relation_pairs_into(&rows, &mut out[..N_PAIRS * N_THRESHOLDS]);
    // pooled context: max and mean per dim
    let mut i = N_PAIRS * N_THRESHOLDS;
    for d in 0..CONTEXT_DIM {
        out[i + d] = rows.iter().map(|r| r[d]).fold(0.0, f64::max);
    }
    i += CONTEXT_DIM;
    for d in 0..CONTEXT_DIM {
        let s: f64 = rows.iter().map(|r| r[d]).sum();
        out[i + d] = s / rows.len().max(1) as f64;
    }
    i += CONTEXT_DIM;
    out[i..].copy_from_slice(&global_features(analysis));
}

/// Context-relation representation: relation pairs + per-dim max/mean
/// pooled context rows + globals. Invariant to loop count and order.
pub fn context_relation(analysis: &ProgramAnalysis) -> Vec<f64> {
    let mut out = vec![0f64; RELATION_DIM];
    context_relation_into(analysis, &mut out);
    out
}

/// Full in-domain representation into a `FULL_DIM` slice.
pub fn full_into(analysis: &ProgramAnalysis, out: &mut [f64]) {
    debug_assert_eq!(out.len(), FULL_DIM);
    let (flat, rel) = out.split_at_mut(FLAT_DIM);
    flat_ast_into(analysis, flat);
    context_relation_into(analysis, rel);
}

/// Full in-domain representation.
pub fn full(analysis: &ProgramAnalysis) -> Vec<f64> {
    let mut out = vec![0f64; FULL_DIM];
    full_into(analysis, &mut out);
    out
}

/// Config-space features padded/truncated to a [`CONFIG_DIM`] slice,
/// same truncation semantics as resizing
/// [`config_features`](crate::schedule::space::ConfigSpace::config_features)
/// (a knob slice straddling the boundary is cut mid-knob).
pub fn config_padded_into(
    space: &crate::schedule::space::ConfigSpace,
    e: &crate::schedule::space::ConfigEntity,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), CONFIG_DIM);
    out.fill(0.0);
    let mut tmp = [0f64; CONFIG_DIM];
    for j in 0..space.num_knobs() {
        let off = space.knob_feature_offset(j);
        if off >= CONFIG_DIM {
            break;
        }
        let dim = space.knob_feature_dim(j);
        let take = dim.min(CONFIG_DIM - off);
        let slice = &mut tmp[..dim.min(CONFIG_DIM)];
        slice.fill(0.0);
        space.knob_features_into(j, e.choices[j], slice);
        out[off..off + take].copy_from_slice(&slice[..take]);
    }
}

/// Config-space features padded/truncated to [`CONFIG_DIM`].
pub fn config_padded(
    space: &crate::schedule::space::ConfigSpace,
    e: &crate::schedule::space::ConfigEntity,
) -> Vec<f64> {
    let mut f = vec![0f64; CONFIG_DIM];
    config_padded_into(space, e, &mut f);
    f
}

/// Neural-model input: the context matrix padded to
/// `MAX_LOOPS × CONTEXT_DIM`, row-major (loop-major), plus a validity
/// mask in the first column slot convention used by the JAX model
/// (rows of all zeros are masked by their zero extent feature).
pub fn context_matrix_padded(analysis: &ProgramAnalysis) -> Vec<f32> {
    let rows = context_rows(analysis.longest_chain());
    let mut out = vec![0f32; FLAT_DIM];
    for (l, row) in rows.iter().take(MAX_LOOPS).enumerate() {
        for (d, v) in row.iter().enumerate() {
            out[l * CONTEXT_DIM + d] = *v as f32;
        }
    }
    out
}

/// Extract features for a task + config into a `repr.dim()` slice.
/// `analysis` must be the analysis of the lowered program for `e`.
/// The single emission point of every representation — the fresh batch
/// path and the delta-replay path both end here, so their rows cannot
/// drift.
pub fn extract_into(
    repr: Representation,
    task: &crate::schedule::template::Task,
    e: &crate::schedule::space::ConfigEntity,
    analysis: &ProgramAnalysis,
    out: &mut [f64],
) {
    match repr {
        Representation::Config => config_padded_into(&task.space, e, out),
        Representation::FlatAst => flat_ast_into(analysis, out),
        Representation::ContextRelation => context_relation_into(analysis, out),
        Representation::Full => full_into(analysis, out),
    }
}

/// Extract features for a task + config under a representation.
/// `analysis` must be the analysis of the lowered program for `e`.
pub fn extract(
    repr: Representation,
    task: &crate::schedule::template::Task,
    e: &crate::schedule::space::ConfigEntity,
    analysis: &ProgramAnalysis,
) -> Vec<f64> {
    let mut out = vec![0f64; repr.dim()];
    extract_into(repr, task, e, analysis, &mut out);
    out
}

/// One contiguous row-major feature matrix from [`featurize_batch`]:
/// `rows × dim` values in a single allocation (no per-row `Vec`s), with
/// a per-row validity flag for entities that failed to lower.
pub struct FeatureBatch {
    /// Row width — the representation's [`Representation::dim`].
    pub dim: usize,
    data: Vec<f64>,
    ok: Vec<bool>,
}

impl FeatureBatch {
    /// Number of rows (valid or not).
    pub fn rows(&self) -> usize {
        self.ok.len()
    }

    /// Row `i`, or `None` if its entity failed to lower.
    pub fn row(&self, i: usize) -> Option<&[f64]> {
        self.ok[i].then(|| &self.data[i * self.dim..(i + 1) * self.dim])
    }
}

/// Shared featurization hook: lower + analyze + extract rows for a
/// batch of entities, in parallel over contiguous chunks of one
/// preallocated SoA matrix. One implementation feeds both the tuner's
/// [`Featurizer`](crate::tuner::Featurizer) memo cache and the tuning
/// DB's per-task feature cache. Entities that fail to lower leave a
/// `None` row — that happens only for foreign/corrupt configs replayed
/// from a persisted DB; configs sampled from the task's own space
/// always lower. Row values are independent of the thread count and
/// chunking.
pub fn featurize_batch(
    repr: Representation,
    task: &crate::schedule::template::Task,
    entities: &[crate::schedule::space::ConfigEntity],
) -> FeatureBatch {
    let dim = repr.dim();
    let n = entities.len();
    let mut data = vec![0f64; n * dim];
    let mut ok = vec![false; n];
    let threads = crate::util::default_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        fill_rows(repr, task, entities, &mut data, &mut ok);
    } else {
        let chunk = n.div_ceil(threads);
        std::thread::scope(|s| {
            let mut data_rest: &mut [f64] = &mut data;
            let mut ok_rest: &mut [bool] = &mut ok;
            let mut start = 0;
            while start < n {
                let len = chunk.min(n - start);
                let (d, dr) = data_rest.split_at_mut(len * dim);
                let (o, or) = ok_rest.split_at_mut(len);
                data_rest = dr;
                ok_rest = or;
                let ents = &entities[start..start + len];
                s.spawn(move || fill_rows(repr, task, ents, d, o));
                start += len;
            }
        });
    }
    FeatureBatch { dim, data, ok }
}

/// Lower + analyze + extract one chunk of rows into its slice of the
/// batch matrix, reusing one scratch analysis across the chunk.
fn fill_rows(
    repr: Representation,
    task: &crate::schedule::template::Task,
    entities: &[crate::schedule::space::ConfigEntity],
    data: &mut [f64],
    ok: &mut [bool],
) {
    let dim = repr.dim();
    let mut analysis = ProgramAnalysis { chains: Vec::new() };
    for (i, e) in entities.iter().enumerate() {
        if let Ok(program) = task.lower(e) {
            crate::ast::analysis::analyze_into(&program, &mut analysis);
            extract_into(repr, task, e, &analysis, &mut data[i * dim..(i + 1) * dim]);
            ok[i] = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ast::analysis::analyze;
    use crate::expr::ops;
    use crate::schedule::template::{Task, TemplateKind};
    use crate::util::Rng;

    fn sample_analysis(task: &Task, seed: u64) -> ProgramAnalysis {
        let mut rng = Rng::seed_from_u64(seed);
        let e = task.space.sample(&mut rng);
        analyze(&task.lower(&e).unwrap())
    }

    #[test]
    fn context_rows_shape_and_content() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Cpu);
        let a = sample_analysis(&task, 1);
        let rows = context_rows(a.longest_chain());
        assert_eq!(rows.len(), a.longest_chain().loops.len());
        // first row: top_down = 1 → log2p(1) = 1
        assert_eq!(rows[0][1 + ForKind::COUNT], 1.0);
        // annotation one-hot sums to 1
        for r in &rows {
            let oh: f64 = r[1..1 + ForKind::COUNT].iter().sum();
            assert_eq!(oh, 1.0);
        }
    }

    #[test]
    fn representations_have_declared_dims() {
        let task = Task::new(
            ops::conv2d(ops::Conv2dParams {
                n: 1, h: 14, w: 14, ic: 64, oc: 64, kh: 3, kw: 3, stride: 1, pad: 1,
            }),
            TemplateKind::Gpu,
        );
        let mut rng = Rng::seed_from_u64(3);
        let e = task.space.sample(&mut rng);
        let a = analyze(&task.lower(&e).unwrap());
        for repr in [
            Representation::Config,
            Representation::FlatAst,
            Representation::ContextRelation,
            Representation::Full,
        ] {
            let f = extract(repr, &task, &e, &a);
            assert_eq!(f.len(), repr.dim(), "{repr:?}");
            assert!(f.iter().all(|x| x.is_finite()), "{repr:?} has non-finite");
        }
    }

    #[test]
    fn relation_dim_is_stable_across_op_types() {
        // the transferable representation must have the same dimension
        // for conv and matmul (different loop counts)
        let conv = Task::new(
            ops::conv2d(ops::Conv2dParams {
                n: 1, h: 28, w: 28, ic: 32, oc: 32, kh: 3, kw: 3, stride: 1, pad: 1,
            }),
            TemplateKind::Gpu,
        );
        let mm = Task::new(ops::matmul(256, 256, 256), TemplateKind::Gpu);
        let ac = sample_analysis(&conv, 5);
        let am = sample_analysis(&mm, 6);
        assert_ne!(
            ac.longest_chain().loops.len(),
            am.longest_chain().loops.len(),
            "precondition: different nest depths"
        );
        assert_eq!(context_relation(&ac).len(), context_relation(&am).len());
    }

    #[test]
    fn different_configs_have_different_features() {
        let task = Task::new(ops::matmul(128, 128, 128), TemplateKind::Cpu);
        let mut rng = Rng::seed_from_u64(8);
        let e1 = task.space.sample(&mut rng);
        let e2 = task.space.sample(&mut rng);
        assert_ne!(e1, e2);
        let a1 = analyze(&task.lower(&e1).unwrap());
        let a2 = analyze(&task.lower(&e2).unwrap());
        assert_ne!(full(&a1), full(&a2));
    }

    #[test]
    fn config_features_padded_to_fixed_dim() {
        let small = Task::new(ops::relu(&[1024]), TemplateKind::Cpu);
        let big = Task::new(
            ops::conv2d(ops::Conv2dParams {
                n: 1, h: 28, w: 28, ic: 32, oc: 32, kh: 3, kw: 3, stride: 1, pad: 1,
            }),
            TemplateKind::Cpu,
        );
        let mut rng = Rng::seed_from_u64(4);
        let es = small.space.sample(&mut rng);
        let eb = big.space.sample(&mut rng);
        assert_eq!(config_padded(&small.space, &es).len(), CONFIG_DIM);
        assert_eq!(config_padded(&big.space, &eb).len(), CONFIG_DIM);
    }

    #[test]
    fn sketch_id_is_first_config_feature() {
        // On a sketch task, knob 0 is the sketch-id Choice, so the
        // leading Config feature is log2(sid + 1) and distinguishes
        // sketches that share every tiling knob value.
        let task = Task::with_sketches(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let n_sketches = task.sketches.as_ref().unwrap().len() as u64;
        assert!(n_sketches > 1);
        let mut e = task.space.entity(0);
        for sid in 0..n_sketches.min(4) {
            e.choices[0] = sid as u32;
            let f = config_padded(&task.space, &e);
            assert!(
                (f[0] - ((sid + 1) as f64).log2()).abs() < 1e-12,
                "feature {} for sketch id {sid}",
                f[0]
            );
        }
    }

    #[test]
    fn context_matrix_padded_is_f32_flat() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let a = sample_analysis(&task, 9);
        let m = context_matrix_padded(&a);
        assert_eq!(m.len(), FLAT_DIM);
        let n = a.longest_chain().loops.len();
        // rows beyond the real loop count are zero
        for l in n..MAX_LOOPS {
            assert!(m[l * CONTEXT_DIM..(l + 1) * CONTEXT_DIM].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn featurize_batch_matches_single_extract() {
        let task = Task::new(ops::matmul(64, 64, 64), TemplateKind::Gpu);
        let mut rng = Rng::seed_from_u64(11);
        let ents: Vec<_> = (0..6).map(|_| task.space.sample(&mut rng)).collect();
        let batch = featurize_batch(Representation::ContextRelation, &task, &ents);
        assert_eq!(batch.rows(), ents.len());
        assert_eq!(batch.dim, Representation::ContextRelation.dim());
        for (i, e) in ents.iter().enumerate() {
            let row = batch.row(i).expect("space configs lower");
            let a = analyze(&task.lower(e).unwrap());
            let fresh = extract(Representation::ContextRelation, &task, e, &a);
            assert_eq!(row, fresh.as_slice());
        }
    }

    #[test]
    fn nest_depth_fits_max_loops() {
        // worst-case template: conv2d with 4 spatial axes split 3-way and
        // 3 reduce axes split 2-way = 18 leaves; longest chain must still
        // fit reasonably (we tolerate truncation but check real depth)
        let task = Task::new(
            ops::conv2d(ops::Conv2dParams {
                n: 1, h: 56, w: 56, ic: 64, oc: 128, kh: 3, kw: 3, stride: 2, pad: 1,
            }),
            TemplateKind::Gpu,
        );
        let a = sample_analysis(&task, 10);
        // 4*3 + 3*2 = 18 > MAX_LOOPS: flat_ast truncates; relation uses all
        assert!(a.longest_chain().loops.len() <= 18);
        let f = flat_ast(&a);
        assert_eq!(f.len(), FLAT_DIM);
    }
}
