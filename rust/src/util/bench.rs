//! Micro-benchmark harness (criterion is not vendored).
//!
//! Adaptive-iteration timing with warmup, reporting mean / median / p95
//! per iteration in criterion-like one-line format. Benches are plain
//! `harness = false` binaries calling [`Bench::run`].

use std::time::{Duration, Instant};

/// One benchmark group.
pub struct Bench {
    name: String,
    /// Target measurement time per case.
    pub measure_time: Duration,
    /// Warmup time per case before measuring.
    pub warmup_time: Duration,
    results: Vec<(String, Stats)>,
}

/// Summary statistics over per-iteration times (nanoseconds).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Mean per-iteration time.
    pub mean_ns: f64,
    /// Median per-iteration time.
    pub median_ns: f64,
    /// 95th-percentile per-iteration time.
    pub p95_ns: f64,
    /// Iterations measured.
    pub iters: u64,
}

impl Stats {
    /// Items per second at the mean time.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    /// Group named `name`; `BENCH_MEASURE_SECS` overrides the budget.
    pub fn new(name: &str) -> Self {
        // Keep benches fast under `cargo bench` while allowing override.
        let secs: f64 = std::env::var("BENCH_MEASURE_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        Bench {
            name: name.to_string(),
            measure_time: Duration::from_secs_f64(secs),
            warmup_time: Duration::from_secs_f64(secs.min(0.3)),
            results: Vec::new(),
        }
    }

    /// Time `f`, which must return a value that is used (prevents DCE).
    pub fn run<R>(&mut self, case: &str, mut f: impl FnMut() -> R) -> Stats {
        // Warmup and calibration.
        let mut iters_per_batch = 1u64;
        let start = Instant::now();
        while start.elapsed() < self.warmup_time {
            for _ in 0..iters_per_batch {
                std::hint::black_box(f());
            }
            iters_per_batch = (iters_per_batch * 2).min(1 << 20);
        }
        // Measure batches.
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let begin = Instant::now();
        while begin.elapsed() < self.measure_time {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / iters_per_batch as f64;
            samples.push(dt);
            total_iters += iters_per_batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
            median_ns: samples[samples.len() / 2],
            p95_ns: samples[(samples.len() - 1) * 95 / 100],
            iters: total_iters,
        };
        println!(
            "{}/{:<40} time: [{} {} {}]  ({} iters)",
            self.name,
            case,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            stats.iters
        );
        self.results.push((case.to_string(), stats));
        stats
    }

    /// Every `(case, stats)` measured so far, in run order — the perf
    /// harness reads these to emit its `BENCH_<area>.json` artifact.
    pub fn results(&self) -> &[(String, Stats)] {
        &self.results
    }

    /// Report a throughput line for an already-run case.
    pub fn throughput(&self, case: &str, items: f64, unit: &str) {
        if let Some((_, s)) = self.results.iter().find(|(c, _)| c == case) {
            println!(
                "{}/{:<40} thrpt: {:.3e} {unit}/s",
                self.name,
                case,
                s.throughput(items)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("BENCH_MEASURE_SECS", "0.05");
        let mut b = Bench::new("test");
        let s = b.run("noop_loop", || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.iters > 0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
