//! Minimal JSON value, writer and parser (serde_json is not vendored).
//!
//! Supports the full JSON grammar minus exotic number forms; used for
//! the tuning database (JSONL records) and experiment result dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for deterministic dumps).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Member `key` of an object (`None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value truncated to u64, if this is a number.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected '{}' at byte {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if matches!(b, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s}: {e}"))?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            anyhow::ensure!(
                                self.pos + 4 < self.bytes.len(),
                                "truncated \\u escape"
                            );
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let s = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", "conv2d \"x\"".into()),
            ("cost", 1.25.into()),
            ("n", 42u64.into()),
            ("ok", true.into()),
            ("tags", vec!["a", "b"].into()),
            ("nested", Json::obj(vec![("x", Json::Null)])),
        ]);
        let s = v.dump();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_whitespace_and_escapes() {
        let v = Json::parse(" { \"a\" : [ 1 , 2.5 , \"x\\ny\" ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integers_dump_without_decimal() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.5).dump(), "3.5");
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::Str("α→β".into());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }
}
