//! Deterministic xoshiro256++ PRNG (Blackman & Vigna), seeded through
//! SplitMix64 — the crate's single randomness source so every experiment
//! is reproducible from a seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Expand a 64-bit seed into the full state (splitmix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-chain / per-thread RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; unbiased via rejection.
    pub fn gen_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn gen_range(&mut self, range: std::ops::Range<usize>) -> usize {
        assert!(range.start < range.end, "empty range");
        range.start + self.gen_below((range.end - range.start) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0..i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly pick a reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(0..xs.len())]
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), order random.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.gen_range(0..n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_below_uniformish() {
        let mut r = Rng::seed_from_u64(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.gen_below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(5);
        let s = r.sample_indices(100, 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
