//! Self-contained utilities: deterministic RNG, minimal JSON, a scoped
//! thread pool and a micro-benchmark harness.
//!
//! The build is fully offline (vendored crates only), so these replace
//! `rand`, `serde_json`, `rayon` and `criterion` respectively. They are
//! small, tested, and deterministic where it matters for reproducing the
//! paper's experiments.

pub mod bench;
pub mod json;
pub mod rng;

pub use rng::Rng;

/// Run `f` over `items` on `threads` worker threads, preserving order.
///
/// A tiny data-parallel map built on `std::thread::scope` (rayon is not
/// vendored). Used for parallel measurement and GBT split search.
pub fn parallel_map<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let n = items.len();
    let counter = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<Option<R>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    for (o, slot) in out.iter_mut().zip(slots) {
        *o = slot.into_inner().unwrap();
    }
    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

/// Run `f` over the index range `0..n` on `threads` worker threads,
/// preserving order.
///
/// Same work-stealing scheme as [`parallel_map`] but driven by an index
/// range directly, so hot paths (batched GBT prediction) don't have to
/// allocate an index `Vec` just to parallel-map over it.
pub fn parallel_map_range<R: Send>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(&f).collect();
    }
    let counter = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<Option<R>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    for (o, slot) in out.iter_mut().zip(slots) {
        *o = slot.into_inner().unwrap();
    }
    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

/// Number of worker threads to use by default.
///
/// A `PALLAS_THREADS` environment override (any positive integer) wins
/// over the detected hardware parallelism, so benches and CI smokes run
/// at a pinned width regardless of the runner; the coordinator's
/// `--threads N` flag sets the same variable. Unset, unparsable or zero
/// values fall back to [`std::thread::available_parallelism`].
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PALLAS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Quantile of a (will be sorted) slice; q in [0, 1].
pub fn quantile(xs: &mut [f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((xs.len() - 1) as f64 * q).round() as usize;
    xs[idx]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread_path() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_range_matches_serial() {
        let out = parallel_map_range(1000, 8, |i| i * 3);
        assert_eq!(out, (0..1000).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(parallel_map_range(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map_range(3, 1, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn quantile_endpoints() {
        let mut xs = vec![3.0, 1.0, 2.0];
        assert_eq!(quantile(&mut xs, 0.0), 1.0);
        assert_eq!(quantile(&mut xs, 1.0), 3.0);
        assert_eq!(quantile(&mut xs, 0.5), 2.0);
    }
}
