//! # autotvm — Learning to Optimize Tensor Programs
//!
//! A Rust + JAX + Pallas reproduction of *Learning to Optimize Tensor
//! Programs* (Chen et al., NeurIPS 2018) — the AutoTVM paper.
//!
//! The crate implements the paper's full stack from scratch:
//!
//! * a tensor-expression DSL and schedule space ([`expr`], [`schedule`]),
//! * a compiler `g(e, s)` lowering expression + schedule to a low-level
//!   loop AST ([`lower`], [`ast`]),
//! * hardware back-ends `f(x)`: analytic device simulators ([`sim`]), a
//!   real PJRT wall-clock path ([`measure`], [`runtime`]), and the
//!   asynchronous device-farm service every tuning loop shares
//!   ([`measure::service`]): a per-replica worker pool built through
//!   [`MeasurerFactory`](measure::service::MeasurerFactory),
//!   sequence-ordered jobs with bounded in-flight backpressure, and
//!   timeout/retry/quarantine board-fault policies with deterministic
//!   result accounting,
//! * the statistical cost models `f̂(x)`: gradient-boosted trees
//!   ([`gbt`]) and an AOT-compiled neural model executed via PJRT
//!   ([`model`]),
//! * transferable program representations ([`features`]),
//! * the exploration module — parallel simulated annealing,
//!   diversity-aware selection, ε-greedy — plus black-box baselines
//!   ([`explore`]),
//! * the top-level tuning loop with transfer learning ([`tuner`]) in
//!   two drivers sharing one featurization / trial-accounting /
//!   warm-start core: the serial Algorithm-1 reference loop
//!   ([`tuner::Tuner`]) and the pipelined production loop
//!   ([`tuner::pipeline`]) that overlaps exploration, farm measurement
//!   and model refits on three channel-connected stages,
//! * the tuning-record service layer ([`tuner::db`]): a sharded,
//!   thread-safe [`TuningDb`](tuner::db::TuningDb) with O(1) best-config
//!   serving, a JSONL write-ahead log, per-task feature caches, live
//!   record streaming from every loop and automatic cross-workload
//!   transfer warm starts — kept production-sized by WAL compaction +
//!   snapshotting under a [`RetentionPolicy`](tuner::db::RetentionPolicy),
//! * the serving tier ([`tuner::serve`]): a
//!   [`ServeConfig`](tuner::serve::ServeConfig) front-end answering
//!   concurrent best-config / top-k lookups with lock-free latency
//!   histograms, plus the query-storm harness behind `bench_serve` and
//!   the coordinator's `serve` subcommand,
//! * a mini graph compiler for end-to-end workloads ([`graph`],
//!   [`workloads`], [`baselines`]),
//! * the graph-level task scheduler ([`tuner::scheduler`]): one global
//!   trial budget spread across a network's tasks by expected marginal
//!   reduction in end-to-end latency (gradient/bandit-style with an
//!   ε starvation floor, EMA gain smoothing with restart detection),
//!   closing the loop graph → tasks → tuner → db → graph latency — and
//!   overlapping slices *across tasks* through versioned gain snapshots
//!   ([`GainLedger`](tuner::scheduler::GainLedger)): task B proposes
//!   while task A's batches drain on the farm, with bit-for-bit
//!   reproducible allocation decisions.
//!
//! See `README.md` for the quickstart and the paper-section → module
//! map, and `docs/ARCHITECTURE.md` for the data-flow and determinism
//! contracts.

#![warn(missing_docs)]

pub mod ast;
pub mod baselines;
pub mod coordinator;
pub mod explore;
pub mod expr;
pub mod features;
pub mod gbt;
pub mod graph;
pub mod lower;
pub mod measure;
pub mod model;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod tuner;
pub mod util;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
