//! The compiler `g(e, s)`: lower a tensor expression + schedule to a
//! low-level loop [`Program`].
//!
//! The lowering reproduces the structure of the paper's Fig. 1:
//! multi-level tiled loop nests with an init nest at the first reduce
//! boundary, optional register accumulation (`cache_write`), optional
//! shared-memory staging of input tiles (`cache_reads`), and annotation
//! of loops (parallel / GPU bindings / auto-unroll / vectorize). All
//! emitted buffer indices stay affine in the leaf loop variables so the
//! downstream analysis is exact.

use crate::ast::{BufferDecl, ForKind, MemScope, Program, Stmt, Value};
use crate::expr::{BodyExpr, Combiner, ComputeDef, Epilogue, IndexExpr, VarId};
use crate::schedule::Schedule;
use std::collections::HashMap;

/// Lower `def` under `sched` into a program.
pub fn lower(def: &ComputeDef, sched: &Schedule) -> anyhow::Result<Program> {
    let extents: Vec<i64> = def.all_axes().map(|a| a.extent).collect();
    sched.validate(&extents)?;
    let mut ctx = Lowering::new(def, sched);
    ctx.run()
}

/// Per-leaf metadata computed up front.
#[derive(Clone, Debug)]
struct Leaf {
    var: VarId,
    extent: i64,
    is_reduce: bool,
    kind: ForKind,
}

struct Lowering<'a> {
    def: &'a ComputeDef,
    sched: &'a Schedule,
    vars: crate::expr::VarPool,
    /// original axis var -> affine expression over leaf vars
    subst: HashMap<VarId, IndexExpr>,
    /// leaves in schedule order
    leaves: Vec<Leaf>,
    buffers: Vec<BufferDecl>,
}

impl<'a> Lowering<'a> {
    fn new(def: &'a ComputeDef, sched: &'a Schedule) -> Self {
        Lowering {
            def,
            sched,
            vars: def.vars.clone(),
            subst: HashMap::new(),
            leaves: Vec::new(),
            buffers: Vec::new(),
        }
    }

    fn run(&mut self) -> anyhow::Result<Program> {
        self.build_leaves();
        self.buffers.push(BufferDecl {
            name: self.def.output.name.clone(),
            shape: self.def.output.shape.clone(),
            scope: MemScope::Global,
        });
        for t in &self.def.inputs {
            self.buffers.push(BufferDecl {
                name: t.name.clone(),
                shape: t.shape.clone(),
                scope: MemScope::Global,
            });
        }

        let stmts = if self.def.reduce_axes.is_empty() {
            self.emit_elementwise()
        } else {
            self.emit_reduction()?
        };

        Ok(Program {
            name: self.def.name.clone(),
            stmts,
            buffers: self.buffers.clone(),
            vars: self.vars.clone(),
            flops: self.def.total_flops(),
        })
    }

    /// Create leaf vars per split and the original-var substitution map.
    fn build_leaves(&mut self) {
        let axes: Vec<_> = self.def.all_axes().cloned().collect();
        // Leaf vars per (axis, part).
        let mut leaf_vars: Vec<Vec<VarId>> = Vec::new();
        for (ai, ax) in axes.iter().enumerate() {
            let sizes = &self.sched.splits[ai];
            let vars: Vec<VarId> = if sizes.len() == 1 {
                vec![ax.var]
            } else {
                (0..sizes.len())
                    .map(|p| self.vars.fresh(format!("{}.{}", ax.name, p)))
                    .collect()
            };
            // y = Σ_p y_p · Π_{q>p} sizes[q]
            let mut expr = IndexExpr::constant(0);
            let mut stride = 1i64;
            for p in (0..sizes.len()).rev() {
                expr = expr.add(&IndexExpr::scaled_var(vars[p], stride));
                stride *= sizes[p];
            }
            if sizes.len() > 1 {
                self.subst.insert(ax.var, expr);
            }
            leaf_vars.push(vars);
        }
        let ns = self.def.axes.len();
        for rf in &self.sched.order {
            let kind = self
                .sched
                .annotations
                .get(rf)
                .copied()
                .unwrap_or(ForKind::Serial);
            self.leaves.push(Leaf {
                var: leaf_vars[rf.axis][rf.part],
                extent: self.sched.splits[rf.axis][rf.part],
                is_reduce: rf.axis >= ns,
                kind,
            });
        }
    }

    fn substitute_index(&self, e: &IndexExpr) -> IndexExpr {
        let mut out = e.clone();
        for (v, rep) in &self.subst {
            out = out.substitute(*v, rep);
        }
        out
    }

    /// Convert the (substituted) body expression to a low-level value.
    fn body_value(&self, b: &BodyExpr) -> Value {
        match b {
            BodyExpr::Load(a) => Value::Load {
                buffer: a.tensor.clone(),
                indices: a.indices.iter().map(|i| self.substitute_index(i)).collect(),
            },
            BodyExpr::Imm(x) => Value::Imm(*x),
            BodyExpr::Add(a, b) => {
                Value::Add(Box::new(self.body_value(a)), Box::new(self.body_value(b)))
            }
            BodyExpr::Sub(a, b) => {
                Value::Sub(Box::new(self.body_value(a)), Box::new(self.body_value(b)))
            }
            BodyExpr::Mul(a, b) => {
                Value::Mul(Box::new(self.body_value(a)), Box::new(self.body_value(b)))
            }
            BodyExpr::Max(a, b) => {
                Value::Max(Box::new(self.body_value(a)), Box::new(self.body_value(b)))
            }
            BodyExpr::Relu(a) => Value::Relu(Box::new(self.body_value(a))),
            BodyExpr::Select(pred, a, b) => Value::Guarded {
                bounds: pred
                    .bounds
                    .iter()
                    .map(|(e, lo, hi)| (self.substitute_index(e), *lo, *hi))
                    .collect(),
                value: Box::new(self.body_value(a)),
                else_: Box::new(self.body_value(b)),
            },
        }
    }

    /// Output index = substituted original spatial axes.
    fn out_indices(&self) -> Vec<IndexExpr> {
        self.def
            .axes
            .iter()
            .map(|a| self.substitute_index(&IndexExpr::var(a.var)))
            .collect()
    }

    /// Wrap a body of statements in the loop for `leaf`, applying
    /// auto-unroll/vectorize overrides.
    fn wrap_loop(&self, leaf: &Leaf, kind: ForKind, body: Vec<Stmt>) -> Stmt {
        Stmt::For { var: leaf.var, extent: leaf.extent, kind, body }
    }

    /// Effective kinds of the main-nest leaves after auto-unroll /
    /// vectorize-inner.
    fn effective_kinds(&self) -> Vec<ForKind> {
        let mut kinds: Vec<ForKind> = self.leaves.iter().map(|l| l.kind).collect();
        if self.sched.vectorize_inner {
            if let Some(last) = kinds.last_mut() {
                if *last == ForKind::Serial {
                    *last = ForKind::Vectorized;
                }
            }
        }
        // auto-unroll innermost serial loops while cumulative extent fits
        let mut cum = 1i64;
        for i in (0..self.leaves.len()).rev() {
            cum = cum.saturating_mul(self.leaves[i].extent);
            if cum > self.sched.unroll_max_step {
                break;
            }
            if kinds[i] == ForKind::Serial {
                kinds[i] = ForKind::Unrolled;
            }
        }
        kinds
    }

    /// Elementwise lowering: single perfect nest, one store.
    fn emit_elementwise(&mut self) -> Vec<Stmt> {
        let kinds = self.effective_kinds();
        let mut value = self.body_value(&self.def.body);
        if let Some(epi) = self.def.epilogue {
            value = apply_epilogue(value, epi);
        }
        let mut body = vec![Stmt::Store {
            buffer: self.def.output.name.clone(),
            indices: self.out_indices(),
            value,
            accumulate: false,
        }];
        for (leaf, kind) in self.leaves.iter().zip(kinds).rev() {
            body = vec![self.wrap_loop(leaf, kind, body)];
        }
        body
    }

    /// Reduction lowering with init / accumulate / writeback structure.
    fn emit_reduction(&mut self) -> anyhow::Result<Vec<Stmt>> {
        let kinds = self.effective_kinds();
        let fr = self
            .leaves
            .iter()
            .position(|l| l.is_reduce)
            .expect("reduction op has reduce leaves");
        // Spatial leaves at positions >= fr form the accumulator tile.
        let tile: Vec<usize> = (fr..self.leaves.len())
            .filter(|&i| !self.leaves[i].is_reduce)
            .collect();

        // Accumulator target.
        let (acc_buf, acc_indices) = if self.sched.cache_write {
            let shape: i64 = tile.iter().map(|&i| self.leaves[i].extent).product();
            let name = format!("{}.acc", self.def.output.name);
            self.buffers.push(BufferDecl {
                name: name.clone(),
                shape: vec![shape.max(1)],
                scope: MemScope::Local,
            });
            // mixed-radix index over tile leaves
            let mut idx = IndexExpr::constant(0);
            let mut stride = 1i64;
            for &i in tile.iter().rev() {
                idx = idx.add(&IndexExpr::scaled_var(self.leaves[i].var, stride));
                stride *= self.leaves[i].extent;
            }
            (name, vec![idx])
        } else {
            (self.def.output.name.clone(), self.out_indices())
        };

        // Shared-memory staging: tensor -> (cached name, remap index).
        let mut cached: HashMap<String, (String, Vec<IndexExpr>)> = HashMap::new();
        let mut copies_at: HashMap<usize, Vec<Stmt>> = HashMap::new();
        for cr in &self.sched.cache_reads {
            let (copy, name, idx) = self.build_cache_copy(cr)?;
            cached.insert(cr.tensor.clone(), (name, idx));
            copies_at.entry(cr.at).or_default().push(copy);
        }

        // Main update statement.
        let raw = self.body_value(&self.def.body);
        let body_val = remap_cached(raw, &cached);
        let update = match self.def.combiner {
            Combiner::Sum => Stmt::Store {
                buffer: acc_buf.clone(),
                indices: acc_indices.clone(),
                value: body_val,
                accumulate: true,
            },
            Combiner::Max => Stmt::Store {
                buffer: acc_buf.clone(),
                indices: acc_indices.clone(),
                value: Value::Max(
                    Box::new(Value::Load {
                        buffer: acc_buf.clone(),
                        indices: acc_indices.clone(),
                    }),
                    Box::new(body_val),
                ),
                accumulate: false,
            },
        };

        // Build the nest from position fr.. inward.
        let mut inner: Vec<Stmt> = vec![update];
        for i in (fr..self.leaves.len()).rev() {
            inner = vec![self.wrap_loop(&self.leaves[i], kinds[i], inner)];
            if let Some(mut copies) = copies_at.remove(&i) {
                copies.append(&mut inner);
                inner = copies;
            }
        }
        // Wrap shared allocs around the whole reduce body.
        for cr in &self.sched.cache_reads {
            let (name, _) = &cached[&cr.tensor];
            inner = vec![Stmt::Alloc { buffer: name.clone(), body: inner }];
        }

        // Init nest over tile leaves.
        let init_val = Value::Imm(self.def.combiner.identity());
        let mut init: Vec<Stmt> = vec![Stmt::Store {
            buffer: acc_buf.clone(),
            indices: acc_indices.clone(),
            value: init_val,
            accumulate: false,
        }];
        for &i in tile.iter().rev() {
            init = vec![self.wrap_loop(&self.leaves[i], self.leaves[i].kind, init)];
        }

        // Writeback / epilogue nest.
        let mut tail: Vec<Stmt> = Vec::new();
        if self.sched.cache_write {
            let mut v = Value::Load { buffer: acc_buf.clone(), indices: acc_indices };
            if let Some(epi) = self.def.epilogue {
                v = apply_epilogue(v, epi);
            }
            let mut wb = vec![Stmt::Store {
                buffer: self.def.output.name.clone(),
                indices: self.out_indices(),
                value: v,
                accumulate: false,
            }];
            for &i in tile.iter().rev() {
                wb = vec![self.wrap_loop(&self.leaves[i], self.leaves[i].kind, wb)];
            }
            tail = wb;
        } else if let Some(epi) = self.def.epilogue {
            let v = apply_epilogue(
                Value::Load {
                    buffer: self.def.output.name.clone(),
                    indices: self.out_indices(),
                },
                epi,
            );
            let mut ep = vec![Stmt::Store {
                buffer: self.def.output.name.clone(),
                indices: self.out_indices(),
                value: v,
                accumulate: false,
            }];
            for &i in tile.iter().rev() {
                ep = vec![self.wrap_loop(&self.leaves[i], self.leaves[i].kind, ep)];
            }
            tail = ep;
        }

        // Body at the first-reduce boundary: init, reduce nest, tail.
        let mut seq = init;
        seq.extend(inner);
        seq.extend(tail);
        // Alloc for the local accumulator wraps the boundary body.
        if self.sched.cache_write {
            seq = vec![Stmt::Alloc { buffer: acc_buf, body: seq }];
        }

        // Outer (pre-boundary) spatial loops.
        for i in (0..fr).rev() {
            seq = vec![self.wrap_loop(&self.leaves[i], kinds[i], seq)];
        }
        Ok(seq)
    }

    /// Build one shared-memory copy nest for `cr`, returning the nest,
    /// the cached buffer name and the remapped inner index.
    fn build_cache_copy(
        &mut self,
        cr: &crate::schedule::CacheRead,
    ) -> anyhow::Result<(Stmt, String, Vec<IndexExpr>)> {
        // Substituted indices of this tensor's access.
        let acc = self
            .def
            .body
            .accesses()
            .into_iter()
            .find(|a| a.tensor == cr.tensor)
            .ok_or_else(|| anyhow::anyhow!("cache read of unused tensor {}", cr.tensor))?;
        let indices: Vec<IndexExpr> =
            acc.indices.iter().map(|i| self.substitute_index(i)).collect();
        // Guard bounds for this tensor (padding), substituted.
        let guard = guard_for(&self.def.body, &cr.tensor)
            .map(|b| {
                b.iter()
                    .map(|(e, lo, hi)| (self.substitute_index(e), *lo, *hi))
                    .collect::<Vec<_>>()
            });

        // Leaves at positions >= cr.at whose var moves this access.
        let moving: Vec<usize> = (cr.at..self.leaves.len())
            .filter(|&i| {
                indices.iter().any(|e| e.coeff(self.leaves[i].var) != 0)
            })
            .collect();
        anyhow::ensure!(!moving.is_empty(), "cache tile for {} is a scalar", cr.tensor);

        let shape: i64 = moving.iter().map(|&i| self.leaves[i].extent).product();
        let name = format!("{}.shared", cr.tensor);
        self.buffers.push(BufferDecl {
            name: name.clone(),
            shape: vec![shape],
            scope: MemScope::Shared,
        });
        // mixed-radix cached index over moving leaves
        let mut idx = IndexExpr::constant(0);
        let mut stride = 1i64;
        for &i in moving.iter().rev() {
            idx = idx.add(&IndexExpr::scaled_var(self.leaves[i].var, stride));
            stride *= self.leaves[i].extent;
        }

        // Copy nest: loop over moving leaves, global -> shared.
        let mut load = Value::Load { buffer: cr.tensor.clone(), indices };
        if let Some(bounds) = guard {
            load = Value::Guarded {
                bounds,
                value: Box::new(load),
                else_: Box::new(Value::Imm(0.0)),
            };
        }
        let mut body = vec![Stmt::Store {
            buffer: name.clone(),
            indices: vec![idx.clone()],
            value: load,
            accumulate: false,
        }];
        for &i in moving.iter().rev() {
            body = vec![Stmt::For {
                var: self.leaves[i].var,
                extent: self.leaves[i].extent,
                kind: self.sched.copy_kind,
                body,
            }];
        }
        Ok((body.pop().unwrap(), name, vec![idx]))
    }
}

/// Replace loads of cached tensors and strip guards that only protected
/// cached loads (the guard moved into the copy nest).
fn remap_cached(v: Value, cached: &HashMap<String, (String, Vec<IndexExpr>)>) -> Value {
    match v {
        Value::Load { buffer, indices } => match cached.get(&buffer) {
            Some((name, idx)) => Value::Load { buffer: name.clone(), indices: idx.clone() },
            None => Value::Load { buffer, indices },
        },
        Value::Imm(x) => Value::Imm(x),
        Value::Add(a, b) => Value::Add(
            Box::new(remap_cached(*a, cached)),
            Box::new(remap_cached(*b, cached)),
        ),
        Value::Sub(a, b) => Value::Sub(
            Box::new(remap_cached(*a, cached)),
            Box::new(remap_cached(*b, cached)),
        ),
        Value::Mul(a, b) => Value::Mul(
            Box::new(remap_cached(*a, cached)),
            Box::new(remap_cached(*b, cached)),
        ),
        Value::Max(a, b) => Value::Max(
            Box::new(remap_cached(*a, cached)),
            Box::new(remap_cached(*b, cached)),
        ),
        Value::Relu(a) => Value::Relu(Box::new(remap_cached(*a, cached))),
        Value::Guarded { bounds, value, else_ } => {
            let all_cached = value
                .loads()
                .iter()
                .all(|(b, _)| cached.contains_key(*b));
            if all_cached {
                remap_cached(*value, cached)
            } else {
                Value::Guarded {
                    bounds,
                    value: Box::new(remap_cached(*value, cached)),
                    else_: Box::new(remap_cached(*else_, cached)),
                }
            }
        }
    }
}

/// Find the padding guard bounds protecting `tensor` in the body.
fn guard_for<'a>(
    b: &'a BodyExpr,
    tensor: &str,
) -> Option<&'a [(crate::expr::IndexExpr, i64, i64)]> {
    match b {
        BodyExpr::Select(pred, inner, _) => {
            if inner.accesses().iter().any(|a| a.tensor == tensor) {
                Some(&pred.bounds)
            } else {
                None
            }
        }
        BodyExpr::Add(a, b2)
        | BodyExpr::Sub(a, b2)
        | BodyExpr::Mul(a, b2)
        | BodyExpr::Max(a, b2) => guard_for(a, tensor).or_else(|| guard_for(b2, tensor)),
        BodyExpr::Relu(a) => guard_for(a, tensor),
        BodyExpr::Load(_) | BodyExpr::Imm(_) => None,
    }
}

fn apply_epilogue(v: Value, epi: Epilogue) -> Value {
    match epi {
        Epilogue::Relu => Value::Relu(Box::new(v)),
        Epilogue::BiasRelu => Value::Relu(Box::new(Value::Add(
            Box::new(v),
            Box::new(Value::Imm(0.1)),
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ast::analysis::analyze;
    use crate::expr::ops;
    use crate::schedule::template::{Task, TemplateKind};
    use crate::util::Rng;

    fn matmul_task(t: TemplateKind) -> Task {
        Task::new(ops::matmul(64, 64, 64), t)
    }

    #[test]
    fn lower_matmul_cpu_structure() {
        let task = matmul_task(TemplateKind::Cpu);
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..30 {
            let e = task.space.sample(&mut rng);
            let p = task.lower(&e).unwrap();
            let a = analyze(&p);
            // main chain must read A and B and write something
            let main = a.longest_chain();
            assert!(main.accesses.iter().any(|x| x.buffer == "A" || x.buffer == "A.shared"));
            assert_eq!(p.flops, 2 * 64 * 64 * 64);
        }
    }

    #[test]
    fn lower_matmul_gpu_has_shared_and_local() {
        let task = matmul_task(TemplateKind::Gpu);
        let e = task.space.entity(12345 % task.space.size());
        let p = task.lower(&e).unwrap();
        assert!(p.buffer("A.shared").is_some());
        assert!(p.buffer("B.shared").is_some());
        assert!(p.buffer("C.acc").is_some());
        assert_eq!(p.buffer("A.shared").unwrap().scope, MemScope::Shared);
        assert_eq!(p.buffer("C.acc").unwrap().scope, MemScope::Local);
        // main update chain reads shared, not global
        let a = analyze(&p);
        let main = a
            .chains
            .iter()
            .find(|c| c.accesses[0].buffer == "C.acc" && c.accumulate)
            .expect("accumulate chain");
        assert!(main.access("A.shared").is_some());
        assert!(main.access("A").is_none());
    }

    #[test]
    fn lower_conv_with_padding_guard_in_copy() {
        let p = ops::Conv2dParams {
            n: 1, h: 14, w: 14, ic: 16, oc: 16, kh: 3, kw: 3, stride: 1, pad: 1,
        };
        let task = Task::new(ops::conv2d(p), TemplateKind::Gpu);
        let e = task.space.entity(7);
        let prog = task.lower(&e).unwrap();
        let a = analyze(&prog);
        // the I.shared copy chain carries the padding guard
        let copy = a
            .chains
            .iter()
            .find(|c| c.accesses[0].buffer == "I.shared")
            .expect("copy chain");
        assert!(copy.has_guard);
        // compute chain lost the guard (it moved into the copy)
        let main = a
            .chains
            .iter()
            .find(|c| c.accesses[0].buffer == "O.acc" && c.accumulate)
            .unwrap();
        assert!(!main.has_guard);
    }

    #[test]
    fn unroll_and_vectorize_annotations_applied() {
        let def = ops::matmul(32, 32, 32);
        let task = Task::new(def, TemplateKind::Cpu);
        // craft a config with unroll = 64 and vec = 1 whose inner loops
        // are small enough for the auto-unroll window
        let iu = task.space.knob_index("unroll").unwrap();
        let iv = task.space.knob_index("vec").unwrap();
        let mut e = task.space.entity(0);
        let crate::schedule::space::Knob::Split { options, .. } = &task.space.knobs[0]
        else {
            panic!()
        };
        // y split [4, 8, 1]: the y.2 loop (extent 1) sits inside the
        // vectorized x.2 and is unrollable
        e.choices[0] =
            options.iter().position(|o| o == &vec![4, 8, 1]).unwrap() as u32;
        e.choices[iu] = 3; // 64
        e.choices[iv] = 1;
        let p = task.lower(&e).unwrap();
        let mut has_unrolled = false;
        let mut has_vec = false;
        fn walk(s: &Stmt, u: &mut bool, v: &mut bool) {
            if let Stmt::For { kind, body, .. } = s {
                if *kind == ForKind::Unrolled {
                    *u = true;
                }
                if *kind == ForKind::Vectorized {
                    *v = true;
                }
                for b in body {
                    walk(b, u, v);
                }
            } else if let Stmt::Alloc { body, .. } = s {
                for b in body {
                    walk(b, u, v);
                }
            }
        }
        for s in &p.stmts {
            walk(s, &mut has_unrolled, &mut has_vec);
        }
        assert!(has_vec, "vectorized loop missing:\n{}", p.pretty());
        assert!(has_unrolled, "unrolled loop missing:\n{}", p.pretty());
    }

    #[test]
    fn maxpool_uses_max_combiner() {
        let def = ops::max_pool2d(1, 8, 16, 16, 2, 2);
        let task = Task::new(def, TemplateKind::Cpu);
        let e = task.space.entity(0);
        let p = task.lower(&e).unwrap();
        // find the init store: must be -inf
        fn find_init(s: &Stmt) -> Option<f64> {
            match s {
                Stmt::Store { value: Value::Imm(x), accumulate: false, .. } => Some(*x),
                Stmt::For { body, .. } | Stmt::Alloc { body, .. } => {
                    body.iter().find_map(find_init)
                }
                _ => None,
            }
        }
        let init = p.stmts.iter().find_map(find_init).unwrap();
        assert_eq!(init, f64::NEG_INFINITY);
    }

    #[test]
    fn fused_epilogue_appears_in_writeback() {
        let p = ops::Conv2dParams {
            n: 1, h: 8, w: 8, ic: 8, oc: 8, kh: 1, kw: 1, stride: 1, pad: 0,
        };
        let def = ops::with_epilogue(ops::conv2d(p), crate::expr::Epilogue::Relu);
        let task = Task::new(def, TemplateKind::Gpu);
        let e = task.space.entity(0);
        let prog = task.lower(&e).unwrap();
        assert!(prog.pretty().contains("relu("), "{}", prog.pretty());
    }

    #[test]
    fn trip_count_matches_extent_product() {
        // whatever the schedule, the accumulate chain trip must equal
        // the total iteration domain
        let task = matmul_task(TemplateKind::Cpu);
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..20 {
            let e = task.space.sample(&mut rng);
            let p = task.lower(&e).unwrap();
            let a = analyze(&p);
            let main = a
                .chains
                .iter()
                .filter(|c| c.accumulate || c.accesses[0].buffer.ends_with(".acc"))
                .find(|c| c.accumulate)
                .unwrap();
            assert_eq!(main.trip, (64f64).powi(3));
        }
    }
}
