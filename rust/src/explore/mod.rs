//! Exploration module (§3.3, Algorithm 1): parallel simulated annealing
//! over the config space with the cost model as energy, diversity-aware
//! batch selection (Eq. 3), ε-greedy random injection — plus the
//! black-box baselines of Fig. 4 (random search, genetic algorithm).
//!
//! Invariants:
//! * **Chain persistence** — [`ParallelSa`] keeps its Markov-chain
//!   states across cost-model updates (and, via the incremental tuners,
//!   across budget slices); only the energy function changes between
//!   passes.
//! * **Determinism** — every stochastic choice draws from a caller-
//!   provided seeded [`Rng`]; candidate collection breaks score ties by
//!   insertion index, so results are independent of thread scheduling.
//! * **No re-proposals** — selection operates on candidates the caller
//!   has not measured before; dedup is the tuner's
//!   [`BatchProposer`](crate::tuner::BatchProposer) contract.

use crate::schedule::space::{ConfigEntity, ConfigSpace};
use crate::util::Rng;
use std::collections::HashMap;

/// Batch scorer: maps candidate configs to predicted scores
/// (higher = better). Implemented by the tuner as featurize + model.
pub trait Scorer {
    fn score(&self, entities: &[ConfigEntity]) -> Vec<f64>;

    /// Score single-knob SA neighbors: `proposals[i]` differs from
    /// `parents[i]` in knob `knobs[i]` only. Scorers with an
    /// incremental featurization path (the tuner's: per-knob slice
    /// patching under `Representation::Config`, structure-cached delta
    /// replay of the lowered-program analysis under the program-derived
    /// representations) override this to skip the full re-extraction
    /// per mutation; the default falls back to the full
    /// [`Scorer::score`] path. Must return the identical scores as
    /// `score(proposals)` — SA acceptance (and therefore fixed-seed
    /// determinism) depends on it.
    fn score_neighbors(
        &self,
        parents: &[ConfigEntity],
        proposals: &[ConfigEntity],
        knobs: &[usize],
    ) -> Vec<f64> {
        let _ = (parents, knobs);
        self.score(proposals)
    }
}

impl<F: Fn(&[ConfigEntity]) -> Vec<f64>> Scorer for F {
    fn score(&self, entities: &[ConfigEntity]) -> Vec<f64> {
        self(entities)
    }
}

/// Simulated-annealing parameters (paper appendix: 128 parallel chains,
/// ≤500 steps per run).
#[derive(Clone, Debug)]
pub struct SaParams {
    /// Parallel Markov chains.
    pub n_chains: usize,
    /// Steps per chain per SA pass.
    pub n_steps: usize,
    /// Initial and final temperature of a geometric schedule.
    pub t_start: f64,
    /// Final temperature of the geometric schedule.
    pub t_end: f64,
}

impl Default for SaParams {
    fn default() -> Self {
        SaParams { n_chains: 128, n_steps: 500, t_start: 1.0, t_end: 0.02 }
    }
}

/// Persistent parallel simulated annealing (§3.3: "we make the states of
/// the Markov chains persistent across f̂ updates").
pub struct ParallelSa {
    /// The annealing schedule.
    pub params: SaParams,
    chains: Vec<ConfigEntity>,
    chain_scores: Vec<f64>,
    initialized: bool,
}

impl ParallelSa {
    /// Fresh (uninitialized) chains; the first pass seeds them randomly.
    pub fn new(params: SaParams) -> Self {
        ParallelSa { params, chains: Vec::new(), chain_scores: Vec::new(), initialized: false }
    }

    /// Run one SA pass with the current model as energy; returns the
    /// distinct candidates visited, best-first, up to `top_k`.
    pub fn collect(
        &mut self,
        space: &ConfigSpace,
        scorer: &dyn Scorer,
        top_k: usize,
        rng: &mut Rng,
    ) -> Vec<(ConfigEntity, f64)> {
        let n = self.params.n_chains;
        if !self.initialized {
            self.chains = (0..n).map(|_| space.sample(rng)).collect();
            self.chain_scores = scorer.score(&self.chains);
            self.initialized = true;
        } else {
            // Rescore persistent states under the updated model.
            self.chain_scores = scorer.score(&self.chains);
        }

        let mut visited: HashMap<ConfigEntity, f64> = HashMap::new();
        for (c, &s) in self.chains.iter().zip(&self.chain_scores) {
            visited.insert(c.clone(), s);
        }

        let steps = self.params.n_steps;
        let decay = (self.params.t_end / self.params.t_start)
            .powf(1.0 / steps.max(1) as f64);
        let mut temp = self.params.t_start;
        // Scale the metropolis criterion by the score spread so the
        // schedule is insensitive to the model's output units.
        for _ in 0..steps {
            let mut knobs = Vec::with_capacity(n);
            let proposals: Vec<ConfigEntity> = self
                .chains
                .iter()
                .map(|c| {
                    let (p, j) = space.mutate_knob(c, rng);
                    knobs.push(j);
                    p
                })
                .collect();
            let scores = scorer.score_neighbors(&self.chains, &proposals, &knobs);
            let spread = score_spread(&self.chain_scores).max(1e-9);
            for i in 0..n {
                visited.entry(proposals[i].clone()).or_insert(scores[i]);
                let delta = (scores[i] - self.chain_scores[i]) / spread;
                if delta >= 0.0 || rng.gen_f64() < (delta / temp).exp() {
                    self.chains[i] = proposals[i].clone();
                    self.chain_scores[i] = scores[i];
                }
            }
            temp *= decay;
        }

        let mut out: Vec<(ConfigEntity, f64)> = visited.into_iter().collect();
        // Deterministic order: score descending, config index ascending.
        // Ties at the `top_k` cutoff must not inherit HashMap iteration
        // order, or runs with the same seed diverge (the pipelined
        // tuner's reproducibility guarantee builds on this).
        out.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap()
                .then_with(|| space.index_of(&a.0).cmp(&space.index_of(&b.0)))
        });
        out.truncate(top_k);
        out
    }
}

fn score_spread(scores: &[f64]) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &s in scores {
        lo = lo.min(s);
        hi = hi.max(s);
    }
    if hi > lo {
        hi - lo
    } else {
        hi.abs().max(1.0)
    }
}

/// Diversity-aware selection (Eq. 3): greedily pick `b` candidates from
/// `ranked` (best-first, scores attached) maximizing
/// `Σ score + α · Σ_j |{s_j covered}|`. Submodular ⇒ greedy is
/// (1−1/e)-optimal [29, 22].
pub fn diverse_select(
    num_knobs: usize,
    ranked: &[(ConfigEntity, f64)],
    b: usize,
    alpha: f64,
) -> Vec<ConfigEntity> {
    let b = b.min(ranked.len());
    let mut covered: Vec<std::collections::HashSet<u32>> =
        vec![std::collections::HashSet::new(); num_knobs];
    let mut chosen: Vec<usize> = Vec::with_capacity(b);
    let mut used = vec![false; ranked.len()];
    // Normalize scores so α has a stable meaning across models.
    let spread = {
        let s: Vec<f64> = ranked.iter().map(|r| r.1).collect();
        score_spread(&s)
    };
    for _ in 0..b {
        let mut best: Option<(usize, f64)> = None;
        for (i, (cand, score)) in ranked.iter().enumerate() {
            if used[i] {
                continue;
            }
            let novel = (0..num_knobs)
                .filter(|&j| !covered[j].contains(&cand.component(j)))
                .count() as f64;
            let gain = score / spread + alpha * novel / num_knobs as f64;
            if best.map_or(true, |(_, g)| gain > g) {
                best = Some((i, gain));
            }
        }
        let Some((i, _)) = best else { break };
        used[i] = true;
        for j in 0..num_knobs {
            covered[j].insert(ranked[i].0.component(j));
        }
        chosen.push(i);
    }
    chosen.into_iter().map(|i| ranked[i].0.clone()).collect()
}

/// Plain top-`b` selection (the λ = 1 / no-diversity ablation).
pub fn top_select(ranked: &[(ConfigEntity, f64)], b: usize) -> Vec<ConfigEntity> {
    ranked.iter().take(b).map(|(c, _)| c.clone()).collect()
}

/// Random-search baseline: `b` fresh uniform samples, avoiding
/// duplicates within the batch and against `seen`.
pub fn random_batch(
    space: &ConfigSpace,
    b: usize,
    seen: &std::collections::HashSet<ConfigEntity>,
    rng: &mut Rng,
) -> Vec<ConfigEntity> {
    let mut out = Vec::with_capacity(b);
    let mut local: std::collections::HashSet<ConfigEntity> = Default::default();
    let mut attempts = 0;
    while out.len() < b && attempts < b * 100 {
        attempts += 1;
        let e = space.sample(rng);
        if !seen.contains(&e) && local.insert(e.clone()) {
            out.push(e);
        }
    }
    out
}

/// Genetic-algorithm baseline (Fig. 4 "GA"): elite survival, tournament
/// parent selection, knob-wise crossover + mutation. Each generation
/// proposes one measurement batch.
pub struct Genetic {
    /// Individuals per generation (one measurement batch).
    pub population: usize,
    /// Top individuals preserved across generations.
    pub elite: usize,
    /// Per-knob mutation probability.
    pub mutation_prob: f64,
    pool: Vec<(ConfigEntity, f64)>,
}

impl Genetic {
    /// GA with elite = population/4 and 0.3 mutation probability.
    pub fn new(population: usize) -> Self {
        Genetic { population, elite: population / 4, mutation_prob: 0.3, pool: Vec::new() }
    }

    /// Propose the next generation.
    pub fn propose(&mut self, space: &ConfigSpace, rng: &mut Rng) -> Vec<ConfigEntity> {
        if self.pool.is_empty() {
            return (0..self.population).map(|_| space.sample(rng)).collect();
        }
        self.pool.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let parents: Vec<&ConfigEntity> =
            self.pool.iter().take(self.elite.max(2)).map(|(c, _)| c).collect();
        let mut next = Vec::with_capacity(self.population);
        while next.len() < self.population {
            let pa = parents[rng.gen_range(0..parents.len())];
            let pb = parents[rng.gen_range(0..parents.len())];
            let mut child = space.crossover(pa, pb, rng);
            if rng.gen_bool(self.mutation_prob) {
                child = space.mutate(&child, rng);
            }
            next.push(child);
        }
        next
    }

    /// Report measured fitness back (higher = better).
    pub fn update(&mut self, batch: &[ConfigEntity], fitness: &[f64]) {
        for (c, &f) in batch.iter().zip(fitness) {
            self.pool.push((c.clone(), f));
        }
        // keep the pool bounded
        if self.pool.len() > 4 * self.population {
            self.pool.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            self.pool.truncate(2 * self.population);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::space::{factorizations, Knob};

    fn space() -> ConfigSpace {
        ConfigSpace {
            knobs: vec![
                Knob::Split {
                    name: "a".into(),
                    extent: 64,
                    parts: 2,
                    options: factorizations(64, 2),
                },
                Knob::Split {
                    name: "b".into(),
                    extent: 64,
                    parts: 2,
                    options: factorizations(64, 2),
                },
                Knob::Choice { name: "c".into(), options: vec![0, 1, 2, 3] },
            ],
        }
    }

    /// Toy score: prefers knob choices close to a target.
    fn toy_scorer(space: &ConfigSpace) -> impl Scorer + '_ {
        move |es: &[ConfigEntity]| {
            es.iter()
                .map(|e| {
                    let f = space.config_features(e);
                    // peak at a=(8,8) b=(4,16) c=2
                    -((f[0] - 3.0).powi(2)
                        + (f[1] - 3.0).powi(2)
                        + (f[2] - 2.0).powi(2)
                        + (f[3] - 4.0).powi(2)
                        + (f[4] - (3f64).log2()).powi(2))
                })
                .collect()
        }
    }

    #[test]
    fn sa_finds_high_score_region() {
        let sp = space();
        let scorer = toy_scorer(&sp);
        let mut sa = ParallelSa::new(SaParams {
            n_chains: 16,
            n_steps: 120,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(0);
        let top = sa.collect(&sp, &scorer, 8, &mut rng);
        assert!(!top.is_empty());
        // best found should be near the optimum (score > -0.5)
        assert!(top[0].1 > -0.5, "best score {}", top[0].1);
        // sorted best-first
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn sa_chains_persist() {
        let sp = space();
        let scorer = toy_scorer(&sp);
        let mut sa = ParallelSa::new(SaParams { n_chains: 8, n_steps: 30, ..Default::default() });
        let mut rng = Rng::seed_from_u64(1);
        sa.collect(&sp, &scorer, 4, &mut rng);
        let before = sa.chains.clone();
        sa.collect(&sp, &scorer, 4, &mut rng);
        // chains continue from previous states (same vector length, and
        // they were not re-randomized — they should score at least as
        // well as fresh uniform ones on average)
        assert_eq!(before.len(), sa.chains.len());
    }

    #[test]
    fn diverse_select_covers_more_components() {
        let sp = space();
        // candidates: many near-identical top configs + some diverse ones
        let mut ranked = Vec::new();
        for i in 0..10 {
            let mut e = sp.entity(0);
            e.choices[2] = 0;
            e.choices[0] = 0;
            e.choices[1] = i % 2;
            ranked.push((e, 10.0 - i as f64 * 0.01));
        }
        for i in 0..10 {
            let mut e = sp.entity(0);
            e.choices[0] = (i % 6) as u32 + 1;
            e.choices[1] = (i % 6) as u32 + 1;
            e.choices[2] = (i % 4) as u32;
            ranked.push((e, 9.5));
        }
        let plain = top_select(&ranked, 8);
        let diverse = diverse_select(sp.num_knobs(), &ranked, 8, 2.0);
        let coverage = |sel: &[ConfigEntity]| {
            (0..sp.num_knobs())
                .map(|j| {
                    sel.iter()
                        .map(|e| e.component(j))
                        .collect::<std::collections::HashSet<_>>()
                        .len()
                })
                .sum::<usize>()
        };
        assert!(
            coverage(&diverse) > coverage(&plain),
            "diverse {} !> plain {}",
            coverage(&diverse),
            coverage(&plain)
        );
        assert_eq!(diverse.len(), 8);
    }

    #[test]
    fn random_batch_distinct_and_unseen() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(3);
        let mut seen = std::collections::HashSet::new();
        seen.insert(sp.entity(0));
        let batch = random_batch(&sp, 16, &seen, &mut rng);
        let set: std::collections::HashSet<_> = batch.iter().collect();
        assert_eq!(set.len(), batch.len());
        assert!(!batch.contains(&sp.entity(0)));
    }

    #[test]
    fn ga_improves_over_generations() {
        let sp = space();
        let scorer = toy_scorer(&sp);
        let mut ga = Genetic::new(16);
        let mut rng = Rng::seed_from_u64(4);
        let mut first_best = f64::NEG_INFINITY;
        let mut last_best = f64::NEG_INFINITY;
        for gen in 0..12 {
            let batch = ga.propose(&sp, &mut rng);
            let fit = scorer.score(&batch);
            let best = fit.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if gen == 0 {
                first_best = best;
            }
            last_best = last_best.max(best);
            ga.update(&batch, &fit);
        }
        assert!(
            last_best >= first_best,
            "GA got worse: {last_best} < {first_best}"
        );
    }
}
