//! Exploration module (§3.3, Algorithm 1): parallel simulated annealing
//! over the config space with the cost model as energy, diversity-aware
//! batch selection (Eq. 3), ε-greedy random injection — plus the
//! black-box baselines of Fig. 4 (random search, genetic algorithm).
//!
//! Invariants:
//! * **Chain persistence** — [`ParallelSa`] keeps its Markov-chain
//!   states across cost-model updates (and, via the incremental tuners,
//!   across budget slices); only the energy function changes between
//!   passes.
//! * **Determinism** — every stochastic choice draws from a caller-
//!   provided seeded [`Rng`]; candidate collection breaks score ties by
//!   insertion index, so results are independent of thread scheduling.
//! * **No re-proposals** — selection operates on candidates the caller
//!   has not measured before; dedup is the tuner's
//!   [`BatchProposer`](crate::tuner::BatchProposer) contract.

use crate::schedule::space::{ConfigEntity, ConfigSpace};
use crate::util::Rng;
use std::cmp::Ordering;
use std::collections::HashMap;

/// Which model-guided explorer collects candidates each round:
/// simulated annealing (the paper's §3.3 default) or the Ansor-style
/// evolutionary refiner. Selected via
/// [`TuneOptions`](crate::tuner::TuneOptions) / `--search sa|evo`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SearchKind {
    /// Persistent parallel simulated annealing ([`ParallelSa`]).
    #[default]
    Sa,
    /// Cost-model-ranked evolutionary search ([`Evolutionary`]).
    Evo,
}

impl SearchKind {
    /// Parse a CLI token (`sa` / `evo`).
    pub fn parse(s: &str) -> Option<SearchKind> {
        match s {
            "sa" => Some(SearchKind::Sa),
            "evo" | "evolutionary" => Some(SearchKind::Evo),
            _ => None,
        }
    }

    /// CLI / report name.
    pub fn name(self) -> &'static str {
        match self {
            SearchKind::Sa => "sa",
            SearchKind::Evo => "evo",
        }
    }
}

/// Descending-score total order with every NaN ranked strictly last.
/// The exploration sorts used to call `partial_cmp().unwrap()`, so one
/// NaN model score panicked the tuning loop; `f64::total_cmp` alone
/// would instead rank positive NaN *above* +∞ and let it win selection.
/// This comparator does neither: NaN never panics and never beats a
/// real score.
pub fn cmp_score_desc(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => b.total_cmp(&a),
    }
}

/// Batch scorer: maps candidate configs to predicted scores
/// (higher = better). Implemented by the tuner as featurize + model.
pub trait Scorer {
    fn score(&self, entities: &[ConfigEntity]) -> Vec<f64>;

    /// Score single-knob SA neighbors: `proposals[i]` differs from
    /// `parents[i]` in knob `knobs[i]` only. Scorers with an
    /// incremental featurization path (the tuner's: per-knob slice
    /// patching under `Representation::Config`, structure-cached delta
    /// replay of the lowered-program analysis under the program-derived
    /// representations) override this to skip the full re-extraction
    /// per mutation; the default falls back to the full
    /// [`Scorer::score`] path. Must return the identical scores as
    /// `score(proposals)` — SA acceptance (and therefore fixed-seed
    /// determinism) depends on it.
    fn score_neighbors(
        &self,
        parents: &[ConfigEntity],
        proposals: &[ConfigEntity],
        knobs: &[usize],
    ) -> Vec<f64> {
        let _ = (parents, knobs);
        self.score(proposals)
    }
}

impl<F: Fn(&[ConfigEntity]) -> Vec<f64>> Scorer for F {
    fn score(&self, entities: &[ConfigEntity]) -> Vec<f64> {
        self(entities)
    }
}

/// Simulated-annealing parameters (paper appendix: 128 parallel chains,
/// ≤500 steps per run).
#[derive(Clone, Debug)]
pub struct SaParams {
    /// Parallel Markov chains.
    pub n_chains: usize,
    /// Steps per chain per SA pass.
    pub n_steps: usize,
    /// Initial and final temperature of a geometric schedule.
    pub t_start: f64,
    /// Final temperature of the geometric schedule.
    pub t_end: f64,
}

impl Default for SaParams {
    fn default() -> Self {
        SaParams { n_chains: 128, n_steps: 500, t_start: 1.0, t_end: 0.02 }
    }
}

/// Persistent parallel simulated annealing (§3.3: "we make the states of
/// the Markov chains persistent across f̂ updates").
pub struct ParallelSa {
    /// The annealing schedule.
    pub params: SaParams,
    chains: Vec<ConfigEntity>,
    chain_scores: Vec<f64>,
    initialized: bool,
}

impl ParallelSa {
    /// Fresh (uninitialized) chains; the first pass seeds them randomly.
    pub fn new(params: SaParams) -> Self {
        ParallelSa { params, chains: Vec::new(), chain_scores: Vec::new(), initialized: false }
    }

    /// Run one SA pass with the current model as energy; returns the
    /// distinct candidates visited, best-first, up to `top_k`.
    pub fn collect(
        &mut self,
        space: &ConfigSpace,
        scorer: &dyn Scorer,
        top_k: usize,
        rng: &mut Rng,
    ) -> Vec<(ConfigEntity, f64)> {
        let n = self.params.n_chains;
        if !self.initialized {
            self.chains = (0..n).map(|_| space.sample(rng)).collect();
            self.chain_scores = scorer.score(&self.chains);
            self.initialized = true;
        } else {
            // Rescore persistent states under the updated model.
            self.chain_scores = scorer.score(&self.chains);
        }

        let mut visited: HashMap<ConfigEntity, f64> = HashMap::new();
        for (c, &s) in self.chains.iter().zip(&self.chain_scores) {
            visited.insert(c.clone(), s);
        }

        let steps = self.params.n_steps;
        let decay = (self.params.t_end / self.params.t_start)
            .powf(1.0 / steps.max(1) as f64);
        let mut temp = self.params.t_start;
        // Scale the metropolis criterion by the score spread so the
        // schedule is insensitive to the model's output units.
        for _ in 0..steps {
            let mut knobs = Vec::with_capacity(n);
            let proposals: Vec<ConfigEntity> = self
                .chains
                .iter()
                .map(|c| {
                    let (p, j) = space.mutate_knob(c, rng);
                    knobs.push(j);
                    p
                })
                .collect();
            let scores = scorer.score_neighbors(&self.chains, &proposals, &knobs);
            let spread = score_spread(&self.chain_scores).max(1e-9);
            for i in 0..n {
                visited.entry(proposals[i].clone()).or_insert(scores[i]);
                let delta = (scores[i] - self.chain_scores[i]) / spread;
                // NaN policy: a NaN proposal is always rejected; a chain
                // whose *current* score is NaN (possible when the model
                // emits NaN for its seed state) accepts any non-NaN
                // proposal so the chain can escape instead of computing
                // `delta = NaN` forever. The non-NaN path is unchanged —
                // fixed-seed runs keep their exact RNG stream.
                let accept = if scores[i].is_nan() {
                    false
                } else if self.chain_scores[i].is_nan() {
                    true
                } else {
                    delta >= 0.0 || rng.gen_f64() < (delta / temp).exp()
                };
                if accept {
                    self.chains[i] = proposals[i].clone();
                    self.chain_scores[i] = scores[i];
                }
            }
            temp *= decay;
        }

        let mut out: Vec<(ConfigEntity, f64)> = visited.into_iter().collect();
        // Deterministic order: score descending, config index ascending.
        // Ties at the `top_k` cutoff must not inherit HashMap iteration
        // order, or runs with the same seed diverge (the pipelined
        // tuner's reproducibility guarantee builds on this).
        out.sort_by(|a, b| {
            cmp_score_desc(a.1, b.1)
                .then_with(|| space.index_of(&a.0).cmp(&space.index_of(&b.0)))
        });
        out.truncate(top_k);
        out
    }
}

fn score_spread(scores: &[f64]) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &s in scores {
        lo = lo.min(s);
        hi = hi.max(s);
    }
    if hi > lo {
        hi - lo
    } else {
        hi.abs().max(1.0)
    }
}

/// Diversity-aware selection (Eq. 3): greedily pick `b` candidates from
/// `ranked` (best-first, scores attached) maximizing
/// `Σ score + α · Σ_j |{s_j covered}|`. Submodular ⇒ greedy is
/// (1−1/e)-optimal [29, 22].
pub fn diverse_select(
    num_knobs: usize,
    ranked: &[(ConfigEntity, f64)],
    b: usize,
    alpha: f64,
) -> Vec<ConfigEntity> {
    let b = b.min(ranked.len());
    let mut covered: Vec<std::collections::HashSet<u32>> =
        vec![std::collections::HashSet::new(); num_knobs];
    let mut chosen: Vec<usize> = Vec::with_capacity(b);
    let mut used = vec![false; ranked.len()];
    // Normalize scores so α has a stable meaning across models.
    let spread = {
        let s: Vec<f64> = ranked.iter().map(|r| r.1).collect();
        score_spread(&s)
    };
    for _ in 0..b {
        let mut best: Option<(usize, f64)> = None;
        for (i, (cand, score)) in ranked.iter().enumerate() {
            if used[i] {
                continue;
            }
            let novel = (0..num_knobs)
                .filter(|&j| !covered[j].contains(&cand.component(j)))
                .count() as f64;
            // A NaN score must never be selected while finite candidates
            // remain: the formula would make the whole gain NaN, and the
            // `map_or(true, ..)` seed pick would lock it in (NaN never
            // compares greater, so nothing could displace it).
            let gain = if score.is_nan() {
                f64::NEG_INFINITY
            } else {
                score / spread + alpha * novel / num_knobs as f64
            };
            if best.map_or(true, |(_, g)| gain > g) {
                best = Some((i, gain));
            }
        }
        let Some((i, _)) = best else { break };
        used[i] = true;
        for j in 0..num_knobs {
            covered[j].insert(ranked[i].0.component(j));
        }
        chosen.push(i);
    }
    chosen.into_iter().map(|i| ranked[i].0.clone()).collect()
}

/// Plain top-`b` selection (the λ = 1 / no-diversity ablation).
pub fn top_select(ranked: &[(ConfigEntity, f64)], b: usize) -> Vec<ConfigEntity> {
    ranked.iter().take(b).map(|(c, _)| c.clone()).collect()
}

/// Random-search baseline: `b` fresh uniform samples, avoiding
/// duplicates within the batch and against `seen`.
///
/// Contract: for spaces with `size() <= RANDOM_BATCH_ENUMERATE_MAX`
/// the batch is **exact** — if at least `b` unseen configs remain, `b`
/// are returned (rejection sampling first, then the unseen remainder is
/// enumerated, shuffled, and drained). For larger spaces the fill is
/// best-effort: rejection sampling gives up after `b * 100` attempts,
/// so a nearly-exhausted large space may return fewer than `b` configs
/// (enumerating billions of entities to find the stragglers would cost
/// more than the measurements they buy).
pub fn random_batch(
    space: &ConfigSpace,
    b: usize,
    seen: &std::collections::HashSet<ConfigEntity>,
    rng: &mut Rng,
) -> Vec<ConfigEntity> {
    let mut out = Vec::with_capacity(b);
    let mut local: std::collections::HashSet<ConfigEntity> = Default::default();
    let mut attempts = 0;
    while out.len() < b && attempts < b * 100 {
        attempts += 1;
        let e = space.sample(rng);
        if !seen.contains(&e) && local.insert(e.clone()) {
            out.push(e);
        }
    }
    if out.len() < b && space.size() <= RANDOM_BATCH_ENUMERATE_MAX {
        // Small space: rejection sampling stalled but unseen configs may
        // remain. Enumerate them, shuffle for unbiasedness, top up.
        let mut remainder: Vec<ConfigEntity> = (0..space.size())
            .map(|i| space.entity(i))
            .filter(|e| !seen.contains(e) && !local.contains(e))
            .collect();
        rng.shuffle(&mut remainder);
        for e in remainder.into_iter().take(b - out.len()) {
            out.push(e);
        }
    }
    out
}

/// Spaces at or below this size get the exact [`random_batch`]
/// enumeration fallback.
pub const RANDOM_BATCH_ENUMERATE_MAX: u64 = 4096;

/// Genetic-algorithm baseline (Fig. 4 "GA"): elite survival, tournament
/// parent selection, knob-wise crossover + mutation. Each generation
/// proposes one measurement batch.
pub struct Genetic {
    /// Individuals per generation (one measurement batch).
    pub population: usize,
    /// Top individuals preserved across generations.
    pub elite: usize,
    /// Per-knob mutation probability.
    pub mutation_prob: f64,
    pool: Vec<(ConfigEntity, f64)>,
}

impl Genetic {
    /// GA with elite = population/4 and 0.3 mutation probability.
    pub fn new(population: usize) -> Self {
        Genetic { population, elite: population / 4, mutation_prob: 0.3, pool: Vec::new() }
    }

    /// Propose the next generation.
    pub fn propose(&mut self, space: &ConfigSpace, rng: &mut Rng) -> Vec<ConfigEntity> {
        if self.pool.is_empty() {
            return (0..self.population).map(|_| space.sample(rng)).collect();
        }
        self.pool.sort_by(|a, b| cmp_score_desc(a.1, b.1));
        let parents: Vec<&ConfigEntity> =
            self.pool.iter().take(self.elite.max(2)).map(|(c, _)| c).collect();
        let mut next = Vec::with_capacity(self.population);
        while next.len() < self.population {
            let pa = parents[rng.gen_range(0..parents.len())];
            let pb = parents[rng.gen_range(0..parents.len())];
            let mut child = space.crossover(pa, pb, rng);
            if rng.gen_bool(self.mutation_prob) {
                child = space.mutate(&child, rng);
            }
            next.push(child);
        }
        next
    }

    /// Report measured fitness back (higher = better).
    pub fn update(&mut self, batch: &[ConfigEntity], fitness: &[f64]) {
        for (c, &f) in batch.iter().zip(fitness) {
            self.pool.push((c.clone(), f));
        }
        // keep the pool bounded (NaN fitness sorts last, so truncation
        // evicts NaN individuals first)
        if self.pool.len() > 4 * self.population {
            self.pool.sort_by(|a, b| cmp_score_desc(a.1, b.1));
            self.pool.truncate(2 * self.population);
        }
    }
}

/// Evolutionary-search parameters (Ansor §5: sampled initial
/// population evolved by mutation + crossover, ranked by the learned
/// cost model).
#[derive(Clone, Debug)]
pub struct EvoParams {
    /// Individuals per generation.
    pub population: usize,
    /// Generations per collect pass.
    pub generations: usize,
    /// Top individuals preserved unchanged across generations.
    pub elite: usize,
    /// Probability a crossover child is additionally mutated.
    pub mutation_prob: f64,
}

impl Default for EvoParams {
    fn default() -> Self {
        EvoParams { population: 128, generations: 24, elite: 16, mutation_prob: 0.5 }
    }
}

/// Ansor-style evolutionary refiner: elite survival + tournament parent
/// selection + knob-wise crossover + mutation, with the **cost model**
/// as fitness. Distinct from [`Genetic`], whose fitness is *measured*
/// throughput (the paper's Fig. 4 black-box baseline): `Evolutionary`
/// burns cheap model evaluations between measurement batches, exactly
/// like [`ParallelSa`] — it is the `--search evo` alternative to SA and
/// is drop-in compatible with [`ParallelSa::collect`].
///
/// The population persists across cost-model updates (mirroring SA's
/// chain persistence), so each refit continues from the best designs
/// found so far rather than restarting from uniform samples.
pub struct Evolutionary {
    /// The evolution schedule.
    pub params: EvoParams,
    pool: Vec<ConfigEntity>,
    initialized: bool,
}

impl Evolutionary {
    /// Fresh (uninitialized) population; the first pass samples it
    /// uniformly.
    pub fn new(params: EvoParams) -> Self {
        Evolutionary { params, pool: Vec::new(), initialized: false }
    }

    /// Run one evolution pass with the current model as fitness;
    /// returns the distinct candidates visited, best-first, up to
    /// `top_k`. Same contract and determinism discipline as
    /// [`ParallelSa::collect`]: all randomness from `rng`, ties broken
    /// by config index.
    pub fn collect(
        &mut self,
        space: &ConfigSpace,
        scorer: &dyn Scorer,
        top_k: usize,
        rng: &mut Rng,
    ) -> Vec<(ConfigEntity, f64)> {
        let pop = self.params.population.max(2);
        if !self.initialized {
            self.pool = (0..pop).map(|_| space.sample(rng)).collect();
            self.initialized = true;
        }

        let mut visited: HashMap<ConfigEntity, f64> = HashMap::new();
        for _ in 0..self.params.generations {
            let scores = scorer.score(&self.pool);
            for (c, &s) in self.pool.iter().zip(&scores) {
                visited.entry(c.clone()).or_insert(s);
            }
            // Rank the current generation: best-first, NaN last, ties by
            // config index so results are seed-deterministic.
            let mut ranked: Vec<usize> = (0..self.pool.len()).collect();
            ranked.sort_by(|&a, &b| {
                cmp_score_desc(scores[a], scores[b]).then_with(|| {
                    space.index_of(&self.pool[a]).cmp(&space.index_of(&self.pool[b]))
                })
            });
            let n_elite = self.params.elite.min(self.pool.len());
            let mut next: Vec<ConfigEntity> =
                ranked.iter().take(n_elite).map(|&i| self.pool[i].clone()).collect();
            while next.len() < pop {
                // Tournament of two: `ranked` is best-first, so the
                // smaller position wins.
                let pa = {
                    let x = rng.gen_range(0..ranked.len());
                    let y = rng.gen_range(0..ranked.len());
                    &self.pool[ranked[x.min(y)]]
                };
                let pb = {
                    let x = rng.gen_range(0..ranked.len());
                    let y = rng.gen_range(0..ranked.len());
                    &self.pool[ranked[x.min(y)]]
                };
                let mut child = space.crossover(pa, pb, rng);
                if rng.gen_bool(self.params.mutation_prob) {
                    child = space.mutate(&child, rng);
                }
                next.push(child);
            }
            self.pool = next;
        }
        // Score the final generation too, so the returned ranking sees
        // the newest children.
        let scores = scorer.score(&self.pool);
        for (c, &s) in self.pool.iter().zip(&scores) {
            visited.entry(c.clone()).or_insert(s);
        }

        let mut out: Vec<(ConfigEntity, f64)> = visited.into_iter().collect();
        out.sort_by(|a, b| {
            cmp_score_desc(a.1, b.1)
                .then_with(|| space.index_of(&a.0).cmp(&space.index_of(&b.0)))
        });
        out.truncate(top_k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::space::{factorizations, Knob};

    fn space() -> ConfigSpace {
        ConfigSpace {
            knobs: vec![
                Knob::Split {
                    name: "a".into(),
                    extent: 64,
                    parts: 2,
                    options: factorizations(64, 2),
                },
                Knob::Split {
                    name: "b".into(),
                    extent: 64,
                    parts: 2,
                    options: factorizations(64, 2),
                },
                Knob::Choice { name: "c".into(), options: vec![0, 1, 2, 3] },
            ],
        }
    }

    /// Toy score: prefers knob choices close to a target.
    fn toy_scorer(space: &ConfigSpace) -> impl Scorer + '_ {
        move |es: &[ConfigEntity]| {
            es.iter()
                .map(|e| {
                    let f = space.config_features(e);
                    // peak at a=(8,8) b=(4,16) c=2
                    -((f[0] - 3.0).powi(2)
                        + (f[1] - 3.0).powi(2)
                        + (f[2] - 2.0).powi(2)
                        + (f[3] - 4.0).powi(2)
                        + (f[4] - (3f64).log2()).powi(2))
                })
                .collect()
        }
    }

    #[test]
    fn sa_finds_high_score_region() {
        let sp = space();
        let scorer = toy_scorer(&sp);
        let mut sa = ParallelSa::new(SaParams {
            n_chains: 16,
            n_steps: 120,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(0);
        let top = sa.collect(&sp, &scorer, 8, &mut rng);
        assert!(!top.is_empty());
        // best found should be near the optimum (score > -0.5)
        assert!(top[0].1 > -0.5, "best score {}", top[0].1);
        // sorted best-first
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn sa_chains_persist() {
        let sp = space();
        let scorer = toy_scorer(&sp);
        let mut sa = ParallelSa::new(SaParams { n_chains: 8, n_steps: 30, ..Default::default() });
        let mut rng = Rng::seed_from_u64(1);
        sa.collect(&sp, &scorer, 4, &mut rng);
        let before = sa.chains.clone();
        sa.collect(&sp, &scorer, 4, &mut rng);
        // chains continue from previous states (same vector length, and
        // they were not re-randomized — they should score at least as
        // well as fresh uniform ones on average)
        assert_eq!(before.len(), sa.chains.len());
    }

    #[test]
    fn diverse_select_covers_more_components() {
        let sp = space();
        // candidates: many near-identical top configs + some diverse ones
        let mut ranked = Vec::new();
        for i in 0..10 {
            let mut e = sp.entity(0);
            e.choices[2] = 0;
            e.choices[0] = 0;
            e.choices[1] = i % 2;
            ranked.push((e, 10.0 - i as f64 * 0.01));
        }
        for i in 0..10 {
            let mut e = sp.entity(0);
            e.choices[0] = (i % 6) as u32 + 1;
            e.choices[1] = (i % 6) as u32 + 1;
            e.choices[2] = (i % 4) as u32;
            ranked.push((e, 9.5));
        }
        let plain = top_select(&ranked, 8);
        let diverse = diverse_select(sp.num_knobs(), &ranked, 8, 2.0);
        let coverage = |sel: &[ConfigEntity]| {
            (0..sp.num_knobs())
                .map(|j| {
                    sel.iter()
                        .map(|e| e.component(j))
                        .collect::<std::collections::HashSet<_>>()
                        .len()
                })
                .sum::<usize>()
        };
        assert!(
            coverage(&diverse) > coverage(&plain),
            "diverse {} !> plain {}",
            coverage(&diverse),
            coverage(&plain)
        );
        assert_eq!(diverse.len(), 8);
    }

    #[test]
    fn random_batch_distinct_and_unseen() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(3);
        let mut seen = std::collections::HashSet::new();
        seen.insert(sp.entity(0));
        let batch = random_batch(&sp, 16, &seen, &mut rng);
        let set: std::collections::HashSet<_> = batch.iter().collect();
        assert_eq!(set.len(), batch.len());
        assert!(!batch.contains(&sp.entity(0)));
    }

    #[test]
    fn ga_improves_over_generations() {
        let sp = space();
        let scorer = toy_scorer(&sp);
        let mut ga = Genetic::new(16);
        let mut rng = Rng::seed_from_u64(4);
        let mut first_best = f64::NEG_INFINITY;
        let mut last_best = f64::NEG_INFINITY;
        for gen in 0..12 {
            let batch = ga.propose(&sp, &mut rng);
            let fit = scorer.score(&batch);
            let best = fit.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if gen == 0 {
                first_best = best;
            }
            last_best = last_best.max(best);
            ga.update(&batch, &fit);
        }
        assert!(
            last_best >= first_best,
            "GA got worse: {last_best} < {first_best}"
        );
    }

    #[test]
    fn evo_finds_high_score_region() {
        let sp = space();
        let scorer = toy_scorer(&sp);
        let mut evo = Evolutionary::new(EvoParams {
            population: 32,
            generations: 20,
            elite: 4,
            mutation_prob: 0.5,
        });
        let mut rng = Rng::seed_from_u64(0);
        let top = evo.collect(&sp, &scorer, 8, &mut rng);
        assert!(!top.is_empty());
        assert!(top[0].1 > -0.5, "best score {}", top[0].1);
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn evo_population_persists_across_passes() {
        let sp = space();
        let scorer = toy_scorer(&sp);
        let mut evo = Evolutionary::new(EvoParams {
            population: 16,
            generations: 6,
            elite: 4,
            mutation_prob: 0.5,
        });
        let mut rng = Rng::seed_from_u64(2);
        let first = evo.collect(&sp, &scorer, 4, &mut rng);
        let pool_after_first = evo.pool.clone();
        let second = evo.collect(&sp, &scorer, 4, &mut rng);
        assert_eq!(pool_after_first.len(), evo.pool.len());
        // the second pass starts from the evolved pool, not fresh
        // uniform samples, so it cannot regress below the first best
        assert!(second[0].1 >= first[0].1 - 1e-12);
    }

    /// Scorer that emits NaN whenever the choice knob picks option 0.
    fn nan_scorer(space: &ConfigSpace) -> impl Scorer + '_ {
        let inner = toy_scorer(space);
        move |es: &[ConfigEntity]| {
            es.iter()
                .map(|e| {
                    if e.choices[2] == 0 {
                        f64::NAN
                    } else {
                        inner.score(std::slice::from_ref(e))[0]
                    }
                })
                .collect()
        }
    }

    #[test]
    fn cmp_score_desc_ranks_nan_last() {
        let mut v = vec![f64::NAN, 1.0, f64::INFINITY, -2.0, f64::NAN, f64::NEG_INFINITY];
        v.sort_by(|a, b| cmp_score_desc(*a, *b));
        assert_eq!(v[0], f64::INFINITY);
        assert_eq!(v[1], 1.0);
        assert_eq!(v[2], -2.0);
        assert_eq!(v[3], f64::NEG_INFINITY);
        assert!(v[4].is_nan() && v[5].is_nan());
    }

    #[test]
    fn nan_scores_neither_panic_nor_win_sa() {
        let sp = space();
        let scorer = nan_scorer(&sp);
        let mut sa = ParallelSa::new(SaParams {
            n_chains: 16,
            n_steps: 80,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(11);
        let top = sa.collect(&sp, &scorer, 8, &mut rng);
        assert!(!top.is_empty());
        // a NaN candidate must never outrank real scores
        assert!(!top[0].1.is_nan(), "NaN won SA selection");
        // and the persistent chains must all have escaped NaN states
        for &s in &sa.chain_scores {
            assert!(!s.is_nan(), "SA chain stuck on a NaN score");
        }
    }

    #[test]
    fn nan_scores_neither_panic_nor_win_ga() {
        let sp = space();
        let scorer = nan_scorer(&sp);
        let mut ga = Genetic::new(16);
        let mut rng = Rng::seed_from_u64(12);
        for _ in 0..8 {
            let batch = ga.propose(&sp, &mut rng);
            let fit = scorer.score(&batch);
            ga.update(&batch, &fit);
        }
        // pool is sorted NaN-last inside update/propose; the elite
        // parents drawn next generation must be real-scored when any
        // real score exists
        let batch = ga.propose(&sp, &mut rng);
        assert!(!batch.is_empty());
    }

    #[test]
    fn nan_scores_neither_panic_nor_win_evo() {
        let sp = space();
        let scorer = nan_scorer(&sp);
        let mut evo = Evolutionary::new(EvoParams {
            population: 16,
            generations: 8,
            elite: 4,
            mutation_prob: 0.5,
        });
        let mut rng = Rng::seed_from_u64(13);
        let top = evo.collect(&sp, &scorer, 8, &mut rng);
        assert!(!top.is_empty());
        assert!(!top[0].1.is_nan(), "NaN won evolutionary selection");
    }

    #[test]
    fn diverse_select_never_picks_nan_over_real() {
        let sp = space();
        // NaN candidate listed first — the old seed-pick bug locked it in
        let ranked = vec![(sp.entity(0), f64::NAN), (sp.entity(1), 1.0), (sp.entity(2), 0.5)];
        let sel = diverse_select(sp.num_knobs(), &ranked, 2, 1.0);
        assert_eq!(sel.len(), 2);
        assert!(!sel.contains(&sp.entity(0)), "NaN-scored candidate selected");
    }

    fn degenerate_space() -> ConfigSpace {
        ConfigSpace {
            knobs: vec![
                Knob::Split {
                    name: "a".into(),
                    extent: 1,
                    parts: 2,
                    options: factorizations(1, 2),
                },
                Knob::Choice { name: "c".into(), options: vec![7] },
            ],
        }
    }

    #[test]
    fn sa_terminates_on_all_cardinality_one_space() {
        let sp = degenerate_space();
        assert_eq!(sp.size(), 1);
        let scorer = |es: &[ConfigEntity]| vec![1.0; es.len()];
        let mut sa = ParallelSa::new(SaParams { n_chains: 4, n_steps: 20, ..Default::default() });
        let mut rng = Rng::seed_from_u64(21);
        let top = sa.collect(&sp, &scorer, 4, &mut rng);
        // mutate returns the parent on cardinality-1 knobs, so exactly
        // one distinct config exists
        assert_eq!(top.len(), 1);
    }

    #[test]
    fn evo_terminates_on_all_cardinality_one_space() {
        let sp = degenerate_space();
        let scorer = |es: &[ConfigEntity]| vec![1.0; es.len()];
        let mut evo = Evolutionary::new(EvoParams {
            population: 4,
            generations: 5,
            elite: 2,
            mutation_prob: 0.5,
        });
        let mut rng = Rng::seed_from_u64(22);
        let top = evo.collect(&sp, &scorer, 4, &mut rng);
        assert_eq!(top.len(), 1);
    }

    #[test]
    fn single_knob_space_explores_all_options() {
        let sp = ConfigSpace {
            knobs: vec![Knob::Choice { name: "only".into(), options: vec![0, 1, 2, 3, 4] }],
        };
        let scorer =
            |es: &[ConfigEntity]| es.iter().map(|e| e.choices[0] as f64).collect::<Vec<_>>();
        let mut sa = ParallelSa::new(SaParams { n_chains: 4, n_steps: 40, ..Default::default() });
        let mut rng = Rng::seed_from_u64(23);
        let top = sa.collect(&sp, &scorer, 5, &mut rng);
        assert_eq!(top[0].0.choices[0], 4, "SA missed the single-knob optimum");
        let mut evo = Evolutionary::new(EvoParams {
            population: 16,
            generations: 10,
            elite: 2,
            mutation_prob: 0.9,
        });
        let top = evo.collect(&sp, &scorer, 5, &mut rng);
        assert_eq!(top[0].0.choices[0], 4, "evo missed the single-knob optimum");
    }

    #[test]
    fn diverse_select_with_b_larger_than_ranked() {
        let sp = space();
        let ranked = vec![(sp.entity(0), 1.0), (sp.entity(1), 0.5)];
        let sel = diverse_select(sp.num_knobs(), &ranked, 10, 1.0);
        assert_eq!(sel.len(), 2);
    }

    #[test]
    fn random_batch_fills_nearly_exhausted_small_space() {
        // 64 × 64 = 4096 — the largest space still under the exact
        // contract. With one unseen config left, rejection sampling
        // (b * 100 = 100 attempts at p = 1/4096) all but certainly
        // stalls, so this exercises the enumeration fallback.
        let sp = ConfigSpace {
            knobs: vec![
                Knob::Choice { name: "x".into(), options: (0..64).collect() },
                Knob::Choice { name: "y".into(), options: (0..64).collect() },
            ],
        };
        assert_eq!(sp.size(), RANDOM_BATCH_ENUMERATE_MAX);
        let hole = sp.size() - 1;
        let mut seen = std::collections::HashSet::new();
        for i in 0..sp.size() {
            if i != hole {
                seen.insert(sp.entity(i));
            }
        }
        let mut rng = Rng::seed_from_u64(31);
        let batch = random_batch(&sp, 2, &seen, &mut rng);
        assert_eq!(batch.len(), 1, "under-filled batch on a small space");
        assert_eq!(batch[0], sp.entity(hole));
        // and an exhausted space returns empty, not an infinite loop
        seen.insert(sp.entity(hole));
        let batch = random_batch(&sp, 2, &seen, &mut rng);
        assert!(batch.is_empty());
    }
}
