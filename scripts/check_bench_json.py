#!/usr/bin/env python3
"""Validate BENCH_*.json perf artifacts before CI uploads them.

Usage: check_bench_json.py FILE [FILE ...]

Every file must parse as a JSON object. Files produced by the shared
bench harness (benches/harness.rs) must carry:

  * "area": non-empty string,
  * "cases": non-empty object whose values each have numeric
    "mean_ns" / "median_ns" / "p95_ns" and a positive integer "iters".

BENCH_e2e_tune.json must additionally record the fast-vs-scalar
trajectory: "trials_per_sec_scalar", "trials_per_sec_fast" and
"speedup_trials_per_sec", all positive numbers.

BENCH_features.json and BENCH_sa.json must record the program-repr
delta-featurization trajectory: a positive "speedup_delta_vs_fresh"
(plus the per-representation "context_delta_speedup_128" /
"full_delta_speedup_128" ratios for the features area).

BENCH_serve.json predates the harness and keeps its own shape (see
benches/bench_serve.rs); it is only required to be a JSON object.

Exit status is non-zero on the first malformed file, so the CI bench
smoke job fails instead of uploading garbage.
"""

import json
import os
import sys

HARNESS_STAT_KEYS = ("mean_ns", "median_ns", "p95_ns")
E2E_EXTRA_KEYS = (
    "trials_per_sec_scalar",
    "trials_per_sec_fast",
    "speedup_trials_per_sec",
)
FEATURES_EXTRA_KEYS = (
    "speedup_delta_vs_fresh",
    "context_delta_speedup_128",
    "full_delta_speedup_128",
)
SA_EXTRA_KEYS = ("speedup_delta_vs_fresh",)


def fail(path, msg):
    print(f"check_bench_json: {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_harness_shape(path, doc):
    area = doc.get("area")
    if not isinstance(area, str) or not area:
        fail(path, '"area" must be a non-empty string')
    cases = doc.get("cases")
    if not isinstance(cases, dict) or not cases:
        fail(path, '"cases" must be a non-empty object')
    for name, stats in cases.items():
        if not isinstance(stats, dict):
            fail(path, f'case "{name}" is not an object')
        for key in HARNESS_STAT_KEYS:
            if not is_num(stats.get(key)):
                fail(path, f'case "{name}" missing numeric "{key}"')
        iters = stats.get("iters")
        if not isinstance(iters, (int, float)) or iters < 1:
            fail(path, f'case "{name}" missing positive "iters"')


def check_extras(path, doc, keys):
    for key in keys:
        v = doc.get(key)
        if not is_num(v) or v <= 0:
            fail(path, f'missing positive "{key}" (perf trajectory not recorded)')


def main(paths):
    if not paths:
        print(__doc__.strip().splitlines()[2], file=sys.stderr)
        sys.exit(2)
    for path in paths:
        try:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
        except OSError as e:
            fail(path, f"unreadable: {e}")
        except json.JSONDecodeError as e:
            fail(path, f"malformed JSON: {e}")
        if not isinstance(doc, dict):
            fail(path, "top level is not a JSON object")
        name = os.path.basename(path)
        if name != "BENCH_serve.json":
            check_harness_shape(path, doc)
        if name == "BENCH_e2e_tune.json":
            check_extras(path, doc, E2E_EXTRA_KEYS)
        if name == "BENCH_features.json":
            check_extras(path, doc, FEATURES_EXTRA_KEYS)
        if name == "BENCH_sa.json":
            check_extras(path, doc, SA_EXTRA_KEYS)
        print(f"check_bench_json: {path}: ok")


if __name__ == "__main__":
    main(sys.argv[1:])
