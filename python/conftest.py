# Make the `compile` package importable when pytest runs from the repo
# root (`pytest python/tests/`).
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
