"""AOT compiler: lower the L2 cost model (with its L1 Pallas kernels) to
HLO-text artifacts for the Rust coordinator.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the `xla`
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (``make artifacts`` → ``artifacts/``):
  costmodel_meta.json       dimensions (checked by the Rust loader)
  costmodel_init.f32        initial flat parameter vector
  costmodel_fwd.hlo.txt     (theta, X[128,16,21]) -> (scores,)
  costmodel_train.hlo.txt   one Adam step on the rank loss (Eq. 2)
  costmodel_reg_train.hlo.txt  same with the regression objective
  matmul256_bm*_bn*_bk*.hlo.txt  (--variants) the Pallas tile family the
                            PJRT measurer wall-clocks on real hardware

Python runs ONCE here; it is never on the tuning path.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul_tiled

# Tile grid of the real-hardware measurement family; must match
# rust/src/measure/pjrt.rs.
VARIANT_N = 256
BM_OPTS = [32, 64, 128]
BN_OPTS = [32, 64, 128]
BK_OPTS = [64, 128, 256]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def emit_costmodel(outdir: str) -> None:
    L, D = model.MAX_LOOPS, model.CONTEXT_DIM
    theta = _spec((model.THETA_DIM,))
    scalar = _spec(())

    fwd = jax.jit(model.predict).lower(theta, _spec((model.PRED_BATCH, L, D)))
    with open(os.path.join(outdir, "costmodel_fwd.hlo.txt"), "w") as f:
        f.write(to_hlo_text(fwd))
    print("wrote costmodel_fwd.hlo.txt")

    bt = model.TRAIN_BATCH
    train_args = (theta, theta, theta, scalar, _spec((bt, L, D)), _spec((bt,)), _spec((bt,)))
    train = jax.jit(model.train_step).lower(*train_args)
    with open(os.path.join(outdir, "costmodel_train.hlo.txt"), "w") as f:
        f.write(to_hlo_text(train))
    print("wrote costmodel_train.hlo.txt")

    reg = jax.jit(model.reg_train_step).lower(*train_args)
    with open(os.path.join(outdir, "costmodel_reg_train.hlo.txt"), "w") as f:
        f.write(to_hlo_text(reg))
    print("wrote costmodel_reg_train.hlo.txt")

    init = model.init_theta(seed=0)
    with open(os.path.join(outdir, "costmodel_init.f32"), "wb") as f:
        f.write(bytes(memoryview(jnp.asarray(init, jnp.float32)).cast("B")))
    meta = {
        "theta_dim": int(model.THETA_DIM),
        "pred_batch": model.PRED_BATCH,
        "train_batch": model.TRAIN_BATCH,
        "max_loops": L,
        "context_dim": D,
    }
    with open(os.path.join(outdir, "costmodel_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote costmodel_init.f32 + meta ({meta['theta_dim']} params)")


def emit_variants(outdir: str) -> None:
    """The Fig.-1 schedule family as runnable artifacts: one tiled
    Pallas matmul per block shape, wall-clocked by the PJRT measurer."""
    n = VARIANT_N
    spec = _spec((n, n))
    count = 0
    for bm in BM_OPTS:
        for bn in BN_OPTS:
            for bk in BK_OPTS:
                def fn(a, b, bm=bm, bn=bn, bk=bk):
                    return (matmul_tiled(a, b, bm=bm, bn=bn, bk=bk, strict=True),)

                lowered = jax.jit(fn).lower(spec, spec)
                name = f"matmul{n}_bm{bm}_bn{bn}_bk{bk}.hlo.txt"
                with open(os.path.join(outdir, name), "w") as f:
                    f.write(to_hlo_text(lowered))
                count += 1
    print(f"wrote {count} matmul variant artifacts (N={n})")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--variants",
        action="store_true",
        help="also emit the Pallas matmul tile-variant family",
    )
    ap.add_argument("--skip-costmodel", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    if not args.skip_costmodel:
        emit_costmodel(args.out)
    if args.variants:
        emit_variants(args.out)


if __name__ == "__main__":
    main()
