"""Layer-2: the context-encoded neural cost model in JAX (Fig. 3d).

Each loop level of a lowered tensor program is a context feature row
(Table 2; extracted in Rust, ``features::context_matrix_padded``). The
model embeds each row, classifies it into one of ``M`` memory slots with
a softmax (``out_i = softmax(Wᵀh)_i · h``), sums the scattered vectors
over loop levels, and maps the result to a scalar score with an MLP.
This is the paper's transferable neural representation — the TreeGRU
stand-in (DESIGN.md §Substitution): fixed shapes make it AOT-able.

The dense layers run through the L1 Pallas kernel
(``kernels.matmul_tiled``) so the kernel lowers into the same HLO
artifact the Rust coordinator executes.

Shapes must match the Rust feature extractor:
``MAX_LOOPS = 16``, ``CONTEXT_DIM = 21`` (see rust/src/features/mod.rs).
"""

import jax
import jax.numpy as jnp

from .kernels import matmul_ad

# Feature geometry — keep in sync with rust/src/features/mod.rs.
MAX_LOOPS = 16
CONTEXT_DIM = 21

# Network geometry.
HIDDEN = 64        # loop-level embedding width
SLOTS = 8          # scatter memory slots
HIDDEN2 = 32       # head width

# Batch shapes of the AOT artifacts.
PRED_BATCH = 128   # SA proposal batch (one batch per chain step)
TRAIN_BATCH = 64   # = the paper's measurement batch b

# Adam hyper-parameters.
LR = 1e-3
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8

_SHAPES = {
    "w1": (CONTEXT_DIM, HIDDEN),
    "b1": (HIDDEN,),
    "ws": (HIDDEN, SLOTS),
    "w2": (SLOTS * HIDDEN, HIDDEN2),
    "b2": (HIDDEN2,),
    "w3": (HIDDEN2,),
    "b3": (),
}

THETA_DIM = sum(int(jnp.prod(jnp.array(s, dtype=jnp.int32))) if s else 1
                for s in _SHAPES.values())


def unpack(theta):
    """Slice the flat parameter vector into named arrays."""
    params = {}
    off = 0
    for name, shape in _SHAPES.items():
        n = 1
        for d in shape:
            n *= d
        params[name] = theta[off:off + n].reshape(shape)
        off += n
    assert off == THETA_DIM
    return params


def init_theta(seed: int = 0):
    """He-style init, returned as one flat f32 vector."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in _SHAPES.items():
        key, sub = jax.random.split(key)
        if len(shape) >= 2:
            scale = (2.0 / shape[0]) ** 0.5
            parts.append(scale * jax.random.normal(sub, shape, jnp.float32))
        elif len(shape) == 1:
            parts.append(jnp.zeros(shape, jnp.float32))
        else:
            parts.append(jnp.zeros((1,), jnp.float32))
    return jnp.concatenate([p.reshape(-1) for p in parts])


def forward(theta, x):
    """Scores for a batch of context matrices.

    x: [B, MAX_LOOPS, CONTEXT_DIM] — rows of all zeros are padding
    (their loop-length feature log2(extent+1) is 0 only for absent
    loops, since real loops have extent >= 1 -> feature >= 1).
    Returns [B] f32 scores (higher = faster program).
    """
    p = unpack(theta)
    b = x.shape[0]
    mask = (x[:, :, 0] > 0.0).astype(x.dtype)              # [B, L]
    flat = x.reshape(b * MAX_LOOPS, CONTEXT_DIM)
    # pad the feature dim 21 -> 32 so the Pallas block divides evenly
    flat = jnp.pad(flat, ((0, 0), (0, 32 - CONTEXT_DIM)))
    w1 = jnp.pad(p["w1"], ((0, 32 - CONTEXT_DIM), (0, 0)))
    h = matmul_ad(flat, w1, 256, 64, 32) + p["b1"]  # big blocks: 8 grid steps, not 64
    h = jnp.maximum(h, 0.0).reshape(b, MAX_LOOPS, HIDDEN)   # [B, L, H]
    # softmax scatter into memory slots (Fig. 3d)
    logits = jnp.einsum("blh,hm->blm", h, p["ws"])
    attn = jax.nn.softmax(logits, axis=-1) * mask[:, :, None]
    slots = jnp.einsum("blm,blh->bmh", attn, h)             # [B, M, H]
    z = slots.reshape(b, SLOTS * HIDDEN)
    z = matmul_ad(z, p["w2"], 128, 32, 512) + p["b2"]  # single grid step
    z = jnp.maximum(z, 0.0)
    return z @ p["w3"] + p["b3"]


def rank_loss(theta, x, y, mask):
    """Pairwise logistic rank loss (Eq. 2) over a masked batch."""
    s = forward(theta, x)
    diff = s[:, None] - s[None, :]
    sign = jnp.sign(y[:, None] - y[None, :])
    pair = mask[:, None] * mask[None, :] * (sign != 0.0).astype(s.dtype)
    per = jnp.log1p(jnp.exp(-jnp.clip(sign * diff, -30.0, 30.0)))
    return (per * pair).sum() / jnp.maximum(pair.sum(), 1.0)


def reg_loss(theta, x, y, mask):
    """Masked MSE (the regression objective of the Fig. 5 ablation)."""
    s = forward(theta, x)
    return (((s - y) ** 2) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _adam_step(loss_fn, theta, m, v, step, x, y, mask):
    loss, grads = jax.value_and_grad(loss_fn)(theta, x, y, mask)
    m = BETA1 * m + (1.0 - BETA1) * grads
    v = BETA2 * v + (1.0 - BETA2) * grads * grads
    mhat = m / (1.0 - BETA1 ** step)
    vhat = v / (1.0 - BETA2 ** step)
    theta = theta - LR * mhat / (jnp.sqrt(vhat) + EPS)
    return theta, m, v, loss


def train_step(theta, m, v, step, x, y, mask):
    """One Adam step on the rank loss. All inputs/outputs f32."""
    return _adam_step(rank_loss, theta, m, v, step, x, y, mask)


def reg_train_step(theta, m, v, step, x, y, mask):
    """One Adam step on the regression loss (Fig. 5 ablation)."""
    return _adam_step(reg_loss, theta, m, v, step, x, y, mask)


def predict(theta, x):
    """AOT entry point: 1-tuple so rust unwraps uniformly."""
    return (forward(theta, x),)
