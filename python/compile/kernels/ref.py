"""Pure-jnp oracles for the Pallas kernels and the cost model.

Every Pallas kernel and every model component has a reference here;
pytest asserts allclose between kernel and oracle — the core build-time
correctness signal (nothing ships into ``artifacts/`` untested).
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Oracle for ``matmul_tiled``."""
    return x @ w


def relu_ref(x):
    return jnp.maximum(x, 0.0)


def softmax_ref(x, axis=-1):
    x = x - x.max(axis=axis, keepdims=True)
    e = jnp.exp(x)
    return e / e.sum(axis=axis, keepdims=True)


def rank_loss_ref(scores, y, mask):
    """Eq. 2 of the paper: pairwise logistic rank loss (numpy-style)."""
    diff = scores[:, None] - scores[None, :]
    sign = jnp.sign(y[:, None] - y[None, :])
    pair = mask[:, None] * mask[None, :] * (sign != 0)
    per = jnp.log1p(jnp.exp(-jnp.clip(sign * diff, -30.0, 30.0)))
    denom = jnp.maximum(pair.sum(), 1.0)
    return (per * pair).sum() / denom


def reg_loss_ref(scores, y, mask):
    """Masked mean squared error (the Fig. 5 regression objective)."""
    denom = jnp.maximum(mask.sum(), 1.0)
    return (((scores - y) ** 2) * mask).sum() / denom
