"""L1: Pallas kernels for the compute hot-spots (build-time only)."""

from .matmul_tiled import fit_block, matmul_ad, matmul_tiled, mxu_utilization, vmem_bytes  # noqa: F401
