"""Layer-1 Pallas kernel: tiled matmul — the paper's Fig. 1 example.

The schedule knobs are the block shape ``(bm, bn, bk)`` expressed with
``BlockSpec`` over a 3-D grid: exactly the multi-level tiling the paper
searches over, re-thought for a TPU-shaped machine (HBM↔VMEM staging via
BlockSpec instead of CUDA threadblocks + shared memory; see DESIGN.md
§Hardware-Adaptation). The k axis is the innermost grid dimension and
accumulates into the revisited output block.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that both pytest
(vs ``ref.py``) and the Rust runtime can run. Real-TPU performance is
*estimated* from VMEM footprint / MXU alignment in EXPERIMENTS.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def fit_block(extent: int, block: int) -> int:
    """Largest divisor of ``extent`` that is ≤ ``block`` (blocks must
    tile the problem exactly, like AutoTVM's factorization knobs)."""
    b = min(block, extent)
    while extent % b != 0:
        b -= 1
    return b


def matmul_tiled(x, w, *, bm: int = 32, bn: int = 32, bk: int = 64,
                 strict: bool = False):
    """Tiled matmul ``x @ w`` with VMEM block shape ``(bm, bn, bk)``.

    With ``strict`` the block sizes must divide the problem shape (the
    AutoTVM config space enumerates exact factorizations for the same
    reason); otherwise they are shrunk to the nearest divisor.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    if strict:
        assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
            f"block ({bm},{bn},{bk}) must divide problem ({m},{n},{k})"
        )
    else:
        bm, bn, bk = fit_block(m, bm), fit_block(n, bn), fit_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul_ad(x, w, bm: int = 32, bn: int = 32, bk: int = 64):
    """Differentiable tiled matmul.

    Pallas's JVP rule cannot see through the grid-accumulation pattern
    (`pl.when(program_id)`), so we register a custom VJP whose backward
    pass is *also* two Pallas tiled matmuls (the transposed products) —
    fwd and bwd both lower through the L1 kernel into the cost-model
    artifacts.
    """
    return matmul_tiled(x, w, bm=bm, bn=bn, bk=bk)


def _matmul_ad_fwd(x, w, bm, bn, bk):
    return matmul_tiled(x, w, bm=bm, bn=bn, bk=bk), (x, w)


def _matmul_ad_bwd(bm, bn, bk, res, g):
    x, w = res
    dx = matmul_tiled(g, w.T, bm=bm, bn=bk, bk=bn)
    dw = matmul_tiled(x.T, g, bm=bk, bn=bn, bk=bm)
    return dx, dw


matmul_ad.defvjp(_matmul_ad_fwd, _matmul_ad_bwd)


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Static VMEM footprint of one grid step (perf estimation)."""
    return itemsize * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(bm: int, bn: int, bk: int, dim: int = 128) -> float:
    """Fraction of MXU tiles kept busy by this block shape (perf
    estimation for EXPERIMENTS.md §Perf; real TPU MXU is 128×128)."""

    def frac(e):
        import math

        return e / (dim * math.ceil(e / dim))

    return frac(bm) * frac(bn)
