"""L1 kernel correctness: Pallas tiled matmul vs the pure-jnp oracle,
with a hypothesis sweep over shapes, dtypes and block sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fit_block, matmul_ad, matmul_tiled, vmem_bytes
from compile.kernels.ref import matmul_ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 64), (64, 128, 256), (128, 32, 64)])
def test_matmul_matches_ref_fixed(bm, bn, bk):
    x = rand(0, (256, 256))
    w = rand(1, (256, 256))
    out = matmul_tiled(x, w, bm=bm, bn=bn, bk=bk, strict=True)
    np.testing.assert_allclose(out, matmul_ref(x, w), rtol=1e-4, atol=1e-4)  # split-k reorders the sum


@settings(max_examples=40, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24, 48, 64]),
    n=st.sampled_from([8, 16, 32, 96]),
    k=st.sampled_from([8, 16, 40, 64]),
    bm=st.integers(1, 64),
    bn=st.integers(1, 64),
    bk=st.integers(1, 64),
)
def test_matmul_hypothesis_shapes(m, n, k, bm, bn, bk):
    x = rand(m * 1000 + n, (m, k))
    w = rand(k * 1000 + n, (k, n))
    out = matmul_tiled(x, w, bm=bm, bn=bn, bk=bk)  # blocks auto-fitted
    np.testing.assert_allclose(out, matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_matmul_dtypes(dtype):
    x = rand(3, (64, 64), jnp.float32).astype(dtype)
    w = rand(4, (64, 64), jnp.float32).astype(dtype)
    out = matmul_tiled(x, w, bm=32, bn=32, bk=32)
    ref = matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=tol, atol=tol)


def test_fit_block_divides():
    for extent in [1, 7, 12, 21, 64, 100]:
        for block in [1, 3, 8, 64]:
            b = fit_block(extent, block)
            assert extent % b == 0 and 1 <= b <= max(block, 1)


def test_matmul_ad_gradients_match_jnp():
    x = rand(5, (32, 64))
    w = rand(6, (64, 32))

    def f_pallas(x, w):
        return (matmul_ad(x, w, 16, 16, 32) ** 2).sum()

    def f_ref(x, w):
        return ((x @ w) ** 2).sum()

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-4)


def test_vmem_budget_of_variant_family():
    # every AOT variant must fit a 16 MiB VMEM-like budget
    for bm in [32, 64, 128]:
        for bn in [32, 64, 128]:
            for bk in [64, 128, 256]:
                assert vmem_bytes(bm, bn, bk) <= 16 * 1024 * 1024
