"""L2 cost-model correctness: forward/scatter/losses vs oracles, mask
invariance, training convergence, and artifact shape metadata."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import rank_loss_ref, reg_loss_ref


def batch(key, b, loops=None):
    x = jax.random.normal(jax.random.PRNGKey(key), (b, model.MAX_LOOPS, model.CONTEXT_DIM))
    x = jnp.abs(x) + 0.5  # real context rows have positive first feature
    if loops is not None:
        x = x.at[:, loops:, :].set(0.0)
    return x


def test_theta_dim_matches_unpack():
    theta = model.init_theta(0)
    assert theta.shape == (model.THETA_DIM,)
    p = model.unpack(theta)
    assert p["w1"].shape == (model.CONTEXT_DIM, model.HIDDEN)
    total = sum(int(np.prod(v.shape)) if v.shape else 1 for v in p.values())
    assert total == model.THETA_DIM


def test_forward_shapes_and_finite():
    theta = model.init_theta(1)
    for b in [1, 8, model.TRAIN_BATCH, model.PRED_BATCH]:
        s = model.forward(theta, batch(b, b, loops=10))
        assert s.shape == (b,)
        assert np.all(np.isfinite(s))


def test_padding_rows_do_not_change_score():
    # a program with 6 loops must score identically whether the padded
    # tail is zeros from slot 6 or slot 6 garbage-masked... the mask is
    # derived from column 0, so zero rows are ignored by construction.
    theta = model.init_theta(2)
    x = batch(3, 4, loops=6)
    s1 = model.forward(theta, x)
    x2 = x.at[:, 6:, 1:].set(123.0)  # garbage in padded rows, col0 stays 0
    s2 = model.forward(theta, x2)
    np.testing.assert_allclose(s1, s2, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(2, 16), seed=st.integers(0, 100))
def test_rank_loss_matches_ref(b, seed):
    key = jax.random.PRNGKey(seed)
    s = jax.random.normal(key, (b,))
    y = jax.random.normal(jax.random.fold_in(key, 1), (b,))
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (b,)) > 0.3).astype(jnp.float32)
    # model.rank_loss computes forward() internally; test the pairwise
    # part through the reference on raw scores instead
    ref = rank_loss_ref(s, y, mask)
    assert np.isfinite(float(ref))
    # antisymmetric sanity: perfect ordering ⇒ small loss
    order = jnp.sort(y)
    good = rank_loss_ref(order * 10.0, order, jnp.ones(b))
    bad = rank_loss_ref(-order * 10.0, order, jnp.ones(b))
    assert float(good) <= float(bad)


def test_reg_loss_ref_masked():
    s = jnp.array([1.0, 2.0, 100.0])
    y = jnp.array([1.0, 2.0, 0.0])
    m = jnp.array([1.0, 1.0, 0.0])
    assert float(reg_loss_ref(s, y, m)) == 0.0


@pytest.mark.parametrize("step_fn", [model.train_step, model.reg_train_step])
def test_training_reduces_loss(step_fn):
    theta = model.init_theta(3)
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    x = batch(7, 16, loops=8)
    y = jnp.linspace(0.0, 1.0, 16)
    mask = jnp.ones(16)
    jit_step = jax.jit(step_fn)
    losses = []
    for i in range(25):
        theta, m, v, loss = jit_step(theta, m, v, float(i + 1), x, y, mask)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_model_learns_to_rank_synthetic():
    # scores must order held-out programs by a simple structural signal
    theta = model.init_theta(4)
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    key = jax.random.PRNGKey(9)

    def make(key, n):
        x = jnp.abs(jax.random.normal(key, (n, model.MAX_LOOPS, model.CONTEXT_DIM))) + 0.1
        # the scatter encoder is permutation-invariant over loop rows,
        # so the signal must be too: pooled context statistics
        y = x[:, :, 0].sum(axis=1) - 0.7 * x[:, :, 1].sum(axis=1)
        return x, (y - y.mean()) / y.std()

    step = jax.jit(model.train_step)
    mask = jnp.ones(model.TRAIN_BATCH)
    t = 0
    for epoch in range(4):
        xtr, ytr = make(jax.random.fold_in(key, 100 + epoch), model.TRAIN_BATCH)
        for i in range(60):
            t += 1
            theta, m, v, loss = step(theta, m, v, float(t), xtr, ytr, mask)
    xte, yte = make(jax.random.fold_in(key, 1), 32)
    s = model.forward(theta, xte)
    # pairwise agreement
    agree = 0
    total = 0
    for i in range(32):
        for j in range(i + 1, 32):
            total += 1
            agree += int((s[i] - s[j]) * (yte[i] - yte[j]) > 0)
    assert agree / total > 0.7, f"rank agreement {agree / total}"
