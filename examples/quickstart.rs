//! Quickstart: tune one ResNet-18 conv workload (C6 of Table 1) on the
//! TITAN-X-class simulator with the paper's default method (GBT + rank
//! objective + diversity-aware SA exploration) and print the
//! optimization curve and the winning schedule.
//!
//! Run: `cargo run --release --example quickstart`

use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::TemplateKind;
use autotvm::sim::devices::sim_gpu;
use autotvm::tuner::{tune_gbt, TuneOptions};
use autotvm::workloads;

fn main() -> anyhow::Result<()> {
    let task = workloads::conv_task(6, TemplateKind::Gpu);
    println!("workload: {}  (|S_e| = {:.2e} configs)", task.def.name, task.space.size() as f64);

    let device = sim_gpu();
    let measurer = SimMeasurer::with_seed(device.clone(), 42);
    let options = TuneOptions { n_trials: 320, seed: 42, verbose: true, ..Default::default() };
    let result = tune_gbt(task.clone(), &measurer, options);

    println!("\noptimization curve (best GFLOPS after each batch):");
    for (i, g) in result.curve.iter().enumerate() {
        if (i + 1) % 64 == 0 {
            println!("  {:4} trials: {g:8.1} GFLOPS", i + 1);
        }
    }
    let (best, gflops) = result.best.expect("found a valid schedule");
    println!("\nbest schedule ({gflops:.1} GFLOPS):");
    println!("  {}", task.space.describe(&best));
    println!("\nlowered program:\n{}", task.lower(&best)?.pretty());
    Ok(())
}
