//! Regenerates Fig. 10 (+ supplementary Fig. 12): single-operator
//! performance vs the vendor baseline on every device.
//! Flags: --device sim-gpu|sim-cpu|sim-mali (default: all three), --full.
fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--device") {
        let mut argv = vec!["fig".to_string(), "10".to_string()];
        argv.extend(args);
        return autotvm::coordinator::run(&argv);
    }
    for dev in ["sim-gpu", "sim-cpu", "sim-mali"] {
        let mut argv = vec!["fig".to_string(), "10".to_string(), "--device".into(), dev.into()];
        argv.extend(args.clone());
        autotvm::coordinator::run(&argv)?;
    }
    Ok(())
}
