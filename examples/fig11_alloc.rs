//! Scheduler-aware Fig.-11 companion: gradient-vs-uniform END-TO-END
//! latency curves per network, as a function of the global trial
//! budget. For each network the graph is fused, its weighted task set
//! extracted, and the budget swept from 1 to 8 slices per task under
//! both allocation policies; latency is replayed on the deterministic
//! per-task tuning curves (`TaskCurve`), so the curves are exact — the
//! same simulated farm the scheduler's acceptance tests run against.
//!
//! Emits `fig11_alloc,network,policy,budget,latency_ms` CSV rows plus a
//! per-network summary of the gradient/uniform gap at the final budget.
//!
//! Run: `cargo run --release --example fig11_alloc`
//! (The maintained interactive entry point is `autotvm tune-graph <net>
//! --alloc gradient|uniform`, which runs the real tuning loops.)

use autotvm::schedule::template::TemplateKind;
use autotvm::sim::devices::{sim_gpu, TaskCurve};
use autotvm::tuner::scheduler::{AllocPolicy, CurveExecutor, SchedulerOptions, TaskScheduler};
use autotvm::workloads;

fn main() -> anyhow::Result<()> {
    let dev = sim_gpu();
    let template = TemplateKind::Gpu;
    let slice = 8usize;
    println!("fig11_alloc,network,policy,budget,latency_ms");
    for name in ["resnet18", "mobilenet", "lstm", "dqn", "dcgan"] {
        let graph = workloads::network(name).expect("known network");
        let fused = graph.fuse();
        let mut final_latency = [0.0f64; 2];
        for (pi, policy) in [AllocPolicy::Uniform, AllocPolicy::Gradient]
            .into_iter()
            .enumerate()
        {
            for mult in 1..=8usize {
                let sched = TaskScheduler::from_graph(
                    &fused,
                    &dev,
                    template,
                    SchedulerOptions { budget: 0, slice, policy, ..Default::default() },
                )?;
                let k = sched.plans().len();
                let budget = k * slice * mult;
                let sched = sched.with_budget(budget);
                let mut farm = CurveExecutor::new(
                    sched
                        .plans()
                        .iter()
                        .map(|p| TaskCurve::for_task(&p.task, &dev))
                        .collect(),
                );
                let alloc = sched.run(&mut farm);
                println!(
                    "fig11_alloc,{name},{},{budget},{:.4}",
                    policy.name(),
                    alloc.est_latency * 1e3
                );
                final_latency[pi] = alloc.est_latency;
            }
        }
        let (uni, grad) = (final_latency[0], final_latency[1]);
        println!(
            "# {name}: at the final budget, gradient {:.4} ms vs uniform {:.4} ms \
             ({:.2}% lower)",
            grad * 1e3,
            uni * 1e3,
            (1.0 - grad / uni) * 100.0
        );
    }
    Ok(())
}
