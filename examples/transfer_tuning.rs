//! Domain-specific scenario: a compilation service that has already
//! tuned the early ResNet layers (C1–C6) receives a *new* workload
//! (C7). Compare cold-start tuning vs transfer (Eq. 4 global+local
//! model seeded from the service's database) — §4 / Fig. 8 in
//! miniature, through the public API.
use autotvm::coordinator::experiments::{collect_source_db, transfer_model_from, ExpOpts};
use autotvm::features::Representation;
use autotvm::measure::SimMeasurer;
use autotvm::schedule::template::{Task, TemplateKind};
use autotvm::sim::devices::sim_gpu;
use autotvm::tuner::{TuneOptions, Tuner};
use autotvm::workloads;

fn main() -> anyhow::Result<()> {
    let device = sim_gpu();
    println!("collecting source database from C1..C6 ...");
    let db = collect_source_db(&[1, 2, 3, 4, 5, 6], TemplateKind::Gpu, &device, 192, 0);
    println!("  {} historical records", db.len());

    let source_tasks: Vec<Task> =
        (1..=6).map(|w| workloads::conv_task(w, TemplateKind::Gpu)).collect();
    let refs: Vec<&Task> = source_tasks.iter().collect();
    let target = workloads::conv_task(7, TemplateKind::Gpu);

    let opts = ExpOpts { trials: 192, ..Default::default() };
    let mut o = TuneOptions { n_trials: opts.trials, seed: 1, ..Default::default() };
    o.repr = Representation::Full;

    let measurer = SimMeasurer::with_seed(device.clone(), 77);
    let model = transfer_model_from(&db, &refs, device.name, Representation::Full, usize::MAX, 1);
    let warm = Tuner::new(target.clone(), Box::new(model), o.clone()).tune(&measurer);

    let measurer2 = SimMeasurer::with_seed(device.clone(), 77);
    let cold = autotvm::tuner::tune_gbt(target.clone(), &measurer2, o);

    println!("\n   trials |  transfer | cold-start   (best GFLOPS)");
    for t in [64, 128, 192] {
        println!("   {t:6} | {:9.1} | {:9.1}", warm.best_at(t), cold.best_at(t));
    }
    let goal = warm.best_at(64);
    let t_warm = warm.trials_to_reach(goal).unwrap_or(9999);
    let t_cold = cold.trials_to_reach(goal).unwrap_or(9999);
    println!(
        "\ntransfer reached {goal:.0} GFLOPS in {t_warm} trials; cold start took {t_cold} \
         ({:.1}x speedup)",
        t_cold as f64 / t_warm as f64
    );
    Ok(())
}
