//! Regenerates Fig. 11 — the END-TO-END system driver: extract tasks
//! from each network graph, tune every task, apply operator fusion,
//! and report full-network inference latency vs the vendor baseline
//! (unfused + fixed expert schedules) on every device.
//! Flags: --device ... (default: all three), --trials N, --full.
fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--device") {
        let mut argv = vec!["fig".to_string(), "11".to_string()];
        argv.extend(args);
        return autotvm::coordinator::run(&argv);
    }
    for dev in ["sim-gpu", "sim-cpu", "sim-mali"] {
        let mut argv = vec!["fig".to_string(), "11".to_string(), "--device".into(), dev.into()];
        argv.extend(args.clone());
        autotvm::coordinator::run(&argv)?;
    }
    Ok(())
}
