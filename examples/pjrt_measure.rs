//! Real-hardware tuning demo: the black box `f(x)` is actual wall-clock
//! time of AOT-compiled Pallas matmul tile variants executed through
//! the PJRT CPU client — the full AutoTVM loop against real silicon,
//! not the simulator. Needs `make artifacts` (variant family).
//!
//! Run: `cargo run --release --example pjrt_measure`

fn main() -> anyhow::Result<()> {
    autotvm::coordinator::run(&["pjrt-demo".to_string()])
}
