//! Regenerates Fig. 8 of the paper (see DESIGN.md experiment index).
//! Flags: --full (paper-scale budgets), --all-workloads (supplementary
//! Fig. sweep), --trials N, --neural (include the PJRT neural model).
fn main() -> anyhow::Result<()> {
    let mut argv = vec!["fig".to_string(), "8".to_string()];
    argv.extend(std::env::args().skip(1));
    autotvm::coordinator::run(&argv)
}
